"""Range-query admission server (DESIGN.md §2, §5).

Adapts ``runtime.router.CoaxRouter``'s continuous-batching admission pattern
to range-query traffic: clients ``submit`` rects into a pending pool, the
server ``drain``s the pool in priority-then-FIFO waves of ``max_batch``
queries, and each wave is one fused ``BatchQueryExecutor`` call.  Per-wave
stats mirror the router's so the serving plane exposes one vocabulary
(waves, pending, qps) whether it batches decode requests or index probes.

Writes (DESIGN.md §5): ``insert``/``delete`` enqueue mutations next to the
query pool; ``drain`` applies every queued write at each wave boundary
(``flush_writes``) before forming the wave, so all queries fused into one
wave answer against the same snapshot+delta state — per-wave snapshot
semantics.  A query admitted before a write but drained after it observes
the write; two queries in the same wave can never observe different states.

Durability (DESIGN.md §7): when the index carries a durability plane, the
server fsyncs its WAL right after each wave-boundary flush — the durable
frontier advances in the same per-wave steps as the visibility frontier
(§7.2 fsync contract) — and every ``checkpoint_every`` waves it publishes
a mid-epoch snapshot to bound replay cost.  ``QueryServer.recover`` is the
restart constructor: snapshot + WAL replay, then serve.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import obs
from ..obs.watchdog import PauseWatchdog
from .executor import BatchQueryExecutor

__all__ = ["PendingQuery", "QueryServer"]


@dataclasses.dataclass
class PendingQuery:
    qid: int
    rect: np.ndarray              # (D, 2)
    priority: float
    arrival: float


class QueryServer:
    """Submit range queries and writes, drain them in batched waves.

    Parameters
    ----------
    index : engine handed to ``BatchQueryExecutor`` (COAXIndex, ShardedCOAX
        or baseline).
    max_batch : queries fused per wave.
    backend : forwarded to ``BatchQueryExecutor`` — ``"device"`` serves
        waves from the index's device-resident plan (DESIGN.md §4).
    shards : forwarded to ``BatchQueryExecutor`` — ``K`` serves waves from a
        K-shard scatter-gather plane (DESIGN.md §6), re-partitioning a
        single mutable index when needed; stats gain per-shard rollups.
    checkpoint_every : publish a durability checkpoint (mid-epoch snapshot
        stamped with the journal position, DESIGN.md §7) every this many
        drained waves; None disables the cadence.  No-op unless the index
        has a durability plane attached.
    cache_bytes : byte budget for a §9 semantic result cache on the served
        index (forwarded to ``BatchQueryExecutor``); None leaves it off.
    shutdown : a ``runtime.failure.GracefulShutdown`` to honour: when its
        flag flips (SIGTERM on a managed host), ``drain`` finishes the
        in-flight wave, stops forming new ones, and returns — the caller
        then runs ``close()`` (flush queued writes, fsync the WAL, release
        the handle) and exits cleanly instead of dying mid-wave.
    watchdog : serving-pause monitor (DESIGN.md §10.3) fed one tick per
        completed wave; pauses exceeding N× the trailing median gap raise
        ``serving_pause_total{culprit=...}`` with the responsible
        background span attached.  Defaults to an always-on
        ``obs.PauseWatchdog()``; pass your own to tune factor/callback,
        or ``watchdog=None`` after construction to disable.
    """

    def __init__(self, index, max_batch: int = 64,
                 executor: Optional[BatchQueryExecutor] = None,
                 backend: Optional[str] = None,
                 shards: Optional[int] = None,
                 checkpoint_every: Optional[int] = None,
                 cache_bytes: Optional[int] = None,
                 shutdown=None,
                 watchdog: Optional[PauseWatchdog] = None):
        self.executor = executor or BatchQueryExecutor(
            index, max_batch=max_batch, backend=backend, shards=shards,
            cache_bytes=cache_bytes)
        self.checkpoint_every = checkpoint_every
        self.shutdown = shutdown
        self.watchdog = watchdog if watchdog is not None else PauseWatchdog()
        self.closed = False
        self._pending: Dict[int, PendingQuery] = {}
        self._ids = itertools.count()
        self._write_queue: List[Tuple[int, str, object]] = []
        self._write_ids = itertools.count()
        self.write_results: Dict[int, object] = {}
        self.waves_drained = 0
        self.writes_applied = 0
        self.rows_inserted = 0
        self.rows_deleted = 0
        self.checkpoints_written = 0

    # ------------------------------------------------------------------ #
    @classmethod
    def recover(cls, directory, max_batch: int = 64,
                backend: Optional[str] = None,
                shards: Optional[int] = None,
                checkpoint_every: Optional[int] = None,
                durable: bool = True, **restore_kwargs) -> "QueryServer":
        """Restart constructor (DESIGN.md §7.4): recover the index from a
        durability directory — newest complete snapshot + WAL-tail replay,
        single or sharded, sniffed from the layout — and serve it.  With
        ``durable`` (default) the recovered index resumes journaling where
        the crashed process stopped."""
        from ..storage import restore
        index = restore(directory, backend=backend or "numpy",
                        durable=durable, **restore_kwargs)
        return cls(index, max_batch=max_batch, backend=backend,
                   shards=shards, checkpoint_every=checkpoint_every)

    # ------------------------------------------------------------------ #
    def submit(self, rect: np.ndarray, priority: float = 0.0,
               arrival: Optional[float] = None) -> int:
        """Queue one rect; returns its query id.

        ``arrival`` defaults to ``time.perf_counter()`` — the SAME clock
        the executor's wave timing uses and the one callers supplying
        explicit stamps are documented against.  (It used to default to
        ``time.time()``: epoch-seconds ~1.7e9 vs perf-counter seconds
        meant the drain sort compared stamps from two different clocks,
        so any explicit-arrival query always out-sorted defaults.)"""
        rect = np.asarray(rect, dtype=np.float64)
        if rect.ndim != 2 or rect.shape[1] != 2:
            raise ValueError(f"rect must be (D, 2), got {rect.shape}")
        n_dims = getattr(self.executor.index, "n_dims", None)
        if n_dims is not None and rect.shape[0] != n_dims:
            raise ValueError(f"rect has {rect.shape[0]} dims, index has {n_dims}")
        qid = next(self._ids)
        self._pending[qid] = PendingQuery(
            qid, rect, priority,
            arrival if arrival is not None else time.perf_counter())
        return qid

    def submit_many(self, rects: np.ndarray, priority: float = 0.0) -> List[int]:
        return [self.submit(r, priority=priority) for r in rects]

    def cancel(self, qid: int) -> bool:
        """Remove a pending query before it is drained; True iff it was
        still pending (False: unknown id, or already answered)."""
        return self._pending.pop(qid, None) is not None

    def pin_epoch(self):
        """Open an MVCC read handle on the served index (DESIGN.md §9.3).

        Queued writes are flushed FIRST so the pin captures the state a
        drain at this instant would serve, then the index's ``pin_epoch``
        freezes it: the handle answers bit-identically to now while
        subsequent drains, writes, and background-compaction handoffs move
        the server forward.  Release the handle to free the old epoch."""
        index = self.executor.index
        pin = getattr(index, "pin_epoch", None)
        if pin is None:
            raise TypeError(f"{type(index).__name__} has no pin_epoch")
        self.flush_writes()
        return pin()

    # ------------------------------------------------------------------ #
    # Write admission (DESIGN.md §5)
    # ------------------------------------------------------------------ #
    def insert(self, rows: np.ndarray) -> int:
        """Queue an insert; returns a write id.  The assigned row ids land
        in ``write_results[write_id]`` once the write is applied (at the
        next wave boundary, or an explicit ``flush_writes``)."""
        index = self.executor.index
        if not hasattr(index, "insert"):
            raise TypeError(f"{type(index).__name__} does not support insert")
        rows = np.atleast_2d(np.asarray(rows, dtype=np.float32))
        n_dims = getattr(index, "n_dims", None)
        if n_dims is not None and rows.shape[1] != n_dims:
            raise ValueError(f"rows have {rows.shape[1]} dims, index has {n_dims}")
        wid = next(self._write_ids)
        self._write_queue.append((wid, "insert", rows))
        return wid

    def delete(self, row_ids) -> int:
        """Queue a delete by original row ids; returns a write id.  The
        count of rows actually removed lands in ``write_results``."""
        index = self.executor.index
        if not hasattr(index, "delete"):
            raise TypeError(f"{type(index).__name__} does not support delete")
        wid = next(self._write_ids)
        self._write_queue.append(
            (wid, "delete", np.asarray(row_ids, dtype=np.int64)))
        return wid

    def flush_writes(self) -> Dict[int, object]:
        """Apply every queued write in admission order; returns the results
        of the writes applied by THIS call ({write_id: ids | count}).

        Adjacent queued inserts are COALESCED into one index call: row ids
        are assigned in admission order either way, so the final state is
        identical, and the per-op fixed cost (margin checks, tracker
        update, trigger check, WAL record) is paid once per run of inserts
        instead of once per admission."""
        applied: Dict[int, object] = {}
        index = self.executor.index
        q = self._write_queue
        while q:
            if q[0][1] == "insert":
                run = []
                while q and q[0][1] == "insert":
                    run.append(q.pop(0))
                rows = (run[0][2] if len(run) == 1 else
                        np.concatenate([p for _, _, p in run], axis=0))
                ids = index.insert(rows)
                self.rows_inserted += int(np.asarray(ids).size)
                off = 0
                for wid, _, p in run:
                    applied[wid] = ids[off:off + p.shape[0]]
                    off += p.shape[0]
                self.writes_applied += len(run)
            else:
                wid, _, payload = q.pop(0)
                res = index.delete(payload)
                self.rows_deleted += int(res)
                applied[wid] = res
                self.writes_applied += 1
        self.write_results.update(applied)
        return applied

    # ------------------------------------------------------------------ #
    def _finish_wave(self, wave, answers, dur,
                     results: Dict[int, np.ndarray]) -> None:
        """Drain-side bookkeeping shared by the pipelined and sync paths."""
        for q, ans in zip(wave, answers):
            results[q.qid] = ans
        self.waves_drained += 1
        if self.watchdog is not None:
            self.watchdog.wave_done()          # §10.3 pause detection
        if (dur is not None and self.checkpoint_every
                and self.waves_drained % self.checkpoint_every == 0):
            dur.checkpoint()
            self.checkpoints_written += 1

    def drain(self, max_waves: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Run pending queries to completion (or for ``max_waves`` waves).

        Returns {query_id: sorted row ids} for every query answered.  Wave
        formation is priority-then-FIFO, like the router's admission sort.
        Queued writes are flushed at every wave boundary, so each wave
        observes one consistent index state (per-wave snapshot semantics);
        a durability plane, if attached, fsyncs its WAL at the same
        boundary — the log and the wave agree on what happened (§7.2).

        On the device backend the drain loop is DOUBLE-BUFFERED (DESIGN.md
        §4): each wave is submitted via ``executor.execute_submit`` — one
        fused kernel launch, results left device-resident — and drained one
        wave behind, so wave ``i+1``'s write flush + upload + launch
        overlaps wave ``i``'s kernel.  Snapshot semantics survive the
        overlap because the device plan captures epoch/delta/tombstone
        state at SUBMIT, before the next boundary's writes are flushed.

        With tracing enabled (``obs.enable_tracing``) the whole call is
        one ``server.drain`` span parenting every ``wave`` span the
        executor opens (DESIGN.md §10.2).
        """
        with obs.span("server.drain", pending=len(self._pending)):
            return self._drain(max_waves)

    def _drain(self, max_waves: Optional[int] = None) -> Dict[int, np.ndarray]:
        results: Dict[int, np.ndarray] = {}
        width = self.executor.max_batch
        waves_this_call = 0
        inflight: List[tuple] = []             # [(wave_queries, pending)]
        dur = getattr(self.executor.index, "durable", None)
        while self._pending or self._write_queue:
            if max_waves is not None and waves_this_call >= max_waves:
                break
            if self.shutdown_requested:
                break                      # in-flight waves still collected
            self.flush_writes()
            if dur is not None:
                dur.sync()
            if not self._pending:
                break
            cands = sorted(self._pending.values(),
                           key=lambda q: (-q.priority, q.arrival, q.qid))
            wave = cands[:width]
            rects = np.stack([q.rect for q in wave])
            for q in wave:                     # claimed at formation so the
                del self._pending[q.qid]       # next wave can't re-pick them
            waves_this_call += 1
            pending = self.executor.execute_submit(rects)
            if pending is not None:            # pipelined device path
                inflight.append((wave, pending))
                if len(inflight) >= 2:
                    w, p = inflight.pop(0)
                    self._finish_wave(w, self.executor.execute_collect(p),
                                      dur, results)
                continue
            while inflight:                    # backend flipped mid-drain
                w, p = inflight.pop(0)
                self._finish_wave(w, self.executor.execute_collect(p),
                                  dur, results)
            self._finish_wave(wave, self.executor.execute(rects),
                              dur, results)
        while inflight:
            w, p = inflight.pop(0)
            self._finish_wave(w, self.executor.execute_collect(p),
                              dur, results)
        return results

    # ------------------------------------------------------------------ #
    # Graceful shutdown (DESIGN.md §8.1)
    # ------------------------------------------------------------------ #
    @property
    def shutdown_requested(self) -> bool:
        return self.shutdown is not None and self.shutdown.requested

    def close(self) -> None:
        """Orderly exit: apply every queued write, JOIN any in-flight
        background compaction (installing its epoch — the §5.4 graceful-
        shutdown contract: the compactor's work is never abandoned), fsync
        the journal tail, release the WAL handle.  Idempotent (the
        durability plane's close is), so signal handlers and ``finally``
        blocks can both call it."""
        self.flush_writes()
        fh = getattr(self.executor.index, "finish_handoff", None)
        if fh is not None:
            fh()
        dur = getattr(self.executor.index, "durable", None)
        if dur is not None:
            dur.sync()
            dur.close()
        self.closed = True

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._pending)

    def stats(self) -> dict:
        s = self.executor.stats()
        index = self.executor.index
        s.update(
            pending=len(self._pending),
            waves_drained=self.waves_drained,
            writes_pending=len(self._write_queue),
            writes_applied=self.writes_applied,
            rows_inserted=self.rows_inserted,
            rows_deleted=self.rows_deleted,
            epoch=int(getattr(index, "epoch", 0)),
            compactions=int(getattr(index, "compactions", 0)),
            delta_rows=int(getattr(index, "delta_rows", 0)),
            tombstones=int(getattr(index, "tombstone_count", 0)),
            checkpoints_written=self.checkpoints_written,
            shutdown_requested=self.shutdown_requested,
            closed=self.closed,
        )
        if self.watchdog is not None:
            w = self.watchdog.describe()
            s.update(pauses=w["pauses"],
                     pause_median_gap_s=w["median_gap_s"],
                     last_pause_culprit=w["last_culprit"])
        dur = getattr(index, "durable", None)
        if dur is not None:
            d = dur.describe()
            s.update(
                wal_records=d["wal_records"],
                wal_bytes=d["wal_bytes"],
                wal_pending_bytes=d["wal_pending_bytes"],
                last_snapshot_bytes=d["last_snapshot_bytes"],
            )
        return s
