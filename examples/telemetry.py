"""Telemetry plane walkthrough (DESIGN.md §10).

    PYTHONPATH=src python examples/telemetry.py

Serves a mixed read/write stream with background compaction while the
full telemetry plane is on, then shows the three layers:

1. the metrics registry — Prometheus-style text exposition plus the
   per-stage latency breakdown (probe/search/filter/merge/delta scan)
   the perf PRs steer by;
2. span tracing — the wave timeline, exported as Chrome ``trace_event``
   JSON that chrome://tracing or Perfetto opens directly;
3. the serving-pause watchdog — wave-gap outliers attributed to the
   background span (compaction install, WAL fsync) that overlapped them.
"""
import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro import obs
from repro.core import COAXIndex, CoaxConfig
from repro.data import knn_rect_queries, make_airline
from repro.engine import QueryServer


def main():
    ds = make_airline(60_000, seed=0)
    rects = knn_rect_queries(ds.data, 256, 64, seed=1, sample_cap=50_000)

    tracer = obs.enable_tracing(capacity=16384)   # spans no-op without this
    idx = COAXIndex(ds.data, CoaxConfig(background_compact=True,
                                        compact_min_delta=512,
                                        compact_delta_frac=0.01,
                                        compact_check_rows=64))
    srv = QueryServer(idx, max_batch=64)

    rng = np.random.default_rng(7)
    for _ in range(3):                # enough writes to cross the compaction
        for start in range(0, len(rects), 64):   # trigger at least once
            srv.insert(ds.data[rng.integers(0, len(ds.data), 128)])
            for r in rects[start:start + 64]:
                srv.submit(r)
            srv.drain()
    idx.finish_handoff()

    # -- layer 1: the registry ------------------------------------------ #
    s = srv.stats()
    print(f"served {s['queries']} queries in {s['waves_drained']} "
          f"waves, epoch {idx.epoch}, "
          f"{idx.background_compactions} background compaction(s)")
    print("\nper-stage latency (coax_stage_seconds):")
    hist = obs.stage_hist()
    for series in obs.get_registry().snapshot()[
            "coax_stage_seconds"]["series"]:
        lab = series["labels"]
        summ = hist.summary(**lab)
        print(f"  {lab['stage']:>11}/{lab['backend']}: "
              f"n={summ['count']:<4} p50={summ['p50']*1e6:8.1f}us "
              f"p99={summ['p99']*1e6:8.1f}us total={summ['sum']*1e3:7.2f}ms")
    exposition = obs.get_registry().render_text()
    wal_lines = [l for l in exposition.splitlines()
                 if l.startswith(("coax_compactions",
                                  "coax_handoff_seconds_"))]
    print("\nexposition excerpt (registry.render_text()):")
    for line in wal_lines[:6]:
        print(f"  {line}")

    # -- layer 2: the trace --------------------------------------------- #
    evs = tracer.events()
    ok, problems = tracer.validate()
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    print(f"\ntrace: {len(evs)} spans ({'valid' if ok else problems[:2]}), "
          f"{tracer.dropped} evicted from the ring")
    for name in sorted(by_name):
        spans = by_name[name]
        total = sum(e["t1"] - e["t0"] for e in spans)
        print(f"  {name:<20} x{len(spans):<4} {total*1e3:8.2f}ms total")
    out = Path(tempfile.gettempdir()) / "coax_trace.json"
    out.write_text(json.dumps(tracer.to_chrome()))
    print(f"chrome://tracing timeline written to {out}")

    # -- layer 3: the watchdog ------------------------------------------ #
    wd = srv.watchdog.describe()
    print(f"\nwatchdog: {wd['pauses']} pause(s) over a "
          f"{wd['median_gap_s']*1e3:.2f}ms median wave gap"
          + (f", last culprit {wd['last_culprit']}"
             if wd["last_culprit"] else ""))

    obs.disable_tracing()


if __name__ == "__main__":
    main()
