"""Serving driver: batched request serving with COAX-routed admission.

    PYTHONPATH=src python examples/serve_requests.py            # LM serving
    PYTHONPATH=src python examples/serve_requests.py --durable  # kill-and-resume

Default mode: requests with correlated (arrival, prompt_len,
predicted_decode, priority) attributes stream into the router; admission
queries form length-homogeneous waves through the COAX index (the
serving-plane integration, DESIGN.md §2).

``--durable`` demos the durability plane (DESIGN.md §7): a journaled
``QueryServer`` absorbs query waves and writes, gets "killed" mid-stream —
with its WAL torn mid-record, as a real crash would leave it — and a fresh
process recovers from snapshot + WAL replay, answers the same queries
bit-identically, and keeps serving.
"""
import argparse
import dataclasses
import shutil
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def main_durable():
    """Kill-and-resume: journal, crash (torn WAL tail included), recover."""
    import os

    from repro.core import COAXIndex, CoaxConfig
    from repro.data import knn_rect_queries, make_airline
    from repro.engine import QueryServer
    from repro.storage import read_manifest, latest_snapshot, wal_path

    workdir = Path(tempfile.mkdtemp(prefix="coax_durable_"))
    try:
        ds = make_airline(30_000, seed=7)
        base, pool = ds.data[:25_000], ds.data[25_000:]
        rects = knn_rect_queries(base, 48, 64, seed=1)

        print("== process 1: journaled serving ==")
        idx = COAXIndex(base, CoaxConfig(compact_min_delta=2_000,
                                         compact_delta_frac=0.05))
        idx.attach_durability(workdir)
        srv = QueryServer(idx, max_batch=16, checkpoint_every=2)
        first = {}
        for i in range(4):
            srv.insert(pool[i * 200:(i + 1) * 200])
            srv.delete(np.arange(i * 300, i * 300 + 120))
            for r in rects[i * 12:(i + 1) * 12]:
                first[srv.submit(r)] = r
        answers1 = srv.drain()
        s = srv.stats()
        print(f"  served {s['queries']} queries in {s['waves']} waves; "
              f"inserted {s['rows_inserted']}, deleted {s['rows_deleted']}; "
              f"epoch {s['epoch']}, wal_records {s['wal_records']}, "
              f"checkpoints {s['checkpoints_written']}")

        # the durable frontier is here: everything drained + fsynced.  One
        # more write dies mid-append — tear its record as a crash would —
        # so it was never acknowledged and recovery must NOT contain it.
        expected = {qid: idx.query(r) for qid, r in first.items()}
        srv.insert(pool[900:1100]); srv.flush_writes()
        idx.durable.sync()
        wfile = wal_path(workdir, idx.epoch)
        os.truncate(wfile, wfile.stat().st_size - 9)
        del srv, idx
        print("  ...killed (last WAL record torn mid-append)")

        print("== process 2: recover and resume ==")
        t0 = time.time()
        srv2 = QueryServer.recover(workdir, max_batch=16, checkpoint_every=2)
        dt = time.time() - t0
        man = read_manifest(latest_snapshot(workdir))
        print(f"  recovered in {dt*1e3:.0f} ms from snapshot "
              f"epoch={man['epoch']} wal_seq={man['wal_seq']} "
              f"+ WAL replay; n_rows={srv2.executor.index.n_rows}")
        qids = {srv2.submit(r): qid for qid, r in first.items()}
        answers2 = srv2.drain()
        agree = all(np.array_equal(answers2[q2], expected[q1])
                    for q2, q1 in qids.items())
        print(f"  re-answered {len(qids)} queries: "
              f"{'bit-identical to pre-crash index' if agree else 'MISMATCH'}")
        assert agree
        srv2.insert(pool[1100:1300]); srv2.flush_writes()
        srv2.executor.index.durable.sync()
        print(f"  resumed journaling: "
              f"{srv2.stats()['wal_records']} records in the live WAL")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def main():
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.runtime.serve_loop import ServeConfig, Server

    cfg = dataclasses.replace(
        get_config("h2o-danube-3-4b"),
        n_layers=4, d_model=256, d_ff=768, vocab_size=8192,
        n_heads=8, n_kv_heads=4, head_dim=32, window=256)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    srv = Server(model, params,
                 ServeConfig(batch_size=8, max_new_tokens=24, cache_len=512,
                             eos_token=0))

    rng = np.random.default_rng(7)
    n_requests = 48
    for i in range(n_requests):
        plen = int(rng.choice([16, 24, 48, 96, 192]))
        srv.submit(rng.integers(1, 8000, plen).astype(np.int32),
                   max_new_tokens=int(rng.integers(8, 24)),
                   priority=float(rng.random()))
    print(f"submitted {n_requests} requests; router stats: {srv.router.stats()}")

    t0 = time.time()
    results = srv.run_until_drained()
    dt = time.time() - t0
    toks = sum(r.tokens.size for r in results)
    print(f"served {len(results)} requests in {srv.waves} waves, "
          f"{toks} tokens in {dt:.1f}s ({toks/dt:.0f} tok/s on CPU)")
    by_wave = {}
    for r in results:
        by_wave.setdefault(r.wave, []).append(r.prompt_len)
    for w, lens in sorted(by_wave.items()):
        print(f"  wave {w}: {len(lens)} reqs, prompt lens {sorted(lens)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--durable", action="store_true",
                    help="kill-and-resume durability demo (DESIGN.md §7)")
    args = ap.parse_args()
    main_durable() if args.durable else main()
