"""Serving driver: batched request serving with COAX-routed admission.

    PYTHONPATH=src python examples/serve_requests.py

Requests with correlated (arrival, prompt_len, predicted_decode, priority)
attributes stream into the router; admission queries form length-homogeneous
waves through the COAX index (the serving-plane integration, DESIGN.md §2).
"""
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.serve_loop import ServeConfig, Server


def main():
    cfg = dataclasses.replace(
        get_config("h2o-danube-3-4b"),
        n_layers=4, d_model=256, d_ff=768, vocab_size=8192,
        n_heads=8, n_kv_heads=4, head_dim=32, window=256)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    srv = Server(model, params,
                 ServeConfig(batch_size=8, max_new_tokens=24, cache_len=512,
                             eos_token=0))

    rng = np.random.default_rng(7)
    n_requests = 48
    for i in range(n_requests):
        plen = int(rng.choice([16, 24, 48, 96, 192]))
        srv.submit(rng.integers(1, 8000, plen).astype(np.int32),
                   max_new_tokens=int(rng.integers(8, 24)),
                   priority=float(rng.random()))
    print(f"submitted {n_requests} requests; router stats: {srv.router.stats()}")

    t0 = time.time()
    results = srv.run_until_drained()
    dt = time.time() - t0
    toks = sum(r.tokens.size for r in results)
    print(f"served {len(results)} requests in {srv.waves} waves, "
          f"{toks} tokens in {dt:.1f}s ({toks/dt:.0f} tok/s on CPU)")
    by_wave = {}
    for r in results:
        by_wave.setdefault(r.wave, []).append(r.prompt_len)
    for w, lens in sorted(by_wave.items()):
        print(f"  wave {w}: {len(lens)} reqs, prompt lens {sorted(lens)}")


if __name__ == "__main__":
    main()
