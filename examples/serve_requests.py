"""Serving driver: batched request serving with COAX-routed admission.

    PYTHONPATH=src python examples/serve_requests.py             # LM serving
    PYTHONPATH=src python examples/serve_requests.py --durable   # kill-and-resume
    PYTHONPATH=src python examples/serve_requests.py --failover  # replicated failover

Default mode: requests with correlated (arrival, prompt_len,
predicted_decode, priority) attributes stream into the router; admission
queries form length-homogeneous waves through the COAX index (the
serving-plane integration, DESIGN.md §2).

``--durable`` demos the durability plane (DESIGN.md §7): a journaled
``QueryServer`` absorbs query waves and writes, honours a SIGTERM-style
graceful-shutdown request (finish the wave, flush writes, fsync, close),
then gets "killed" mid-stream — with its WAL torn mid-record, as a real
crash would leave it — and a fresh process recovers from snapshot + WAL
replay, answers the same queries bit-identically, and keeps serving.

``--failover`` demos the replication plane (DESIGN.md §8): a
``ReplicatedServer`` ships WAL frames to two read replicas over a faulty
transport (drops, tears, duplicates, reordering — all repaired), routes
reads to healthy replicas, loses its primary mid-stream, promotes the
most-caught-up replica without losing an acknowledged write, and keeps
serving bit-identical answers.
"""
import argparse
import dataclasses
import shutil
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def main_failover():
    """Replicated serving: faulty shipping, primary death, promotion."""
    from repro.core import COAXIndex, CoaxConfig
    from repro.data import knn_rect_queries, make_airline
    from repro.replication import ReplicatedServer
    from repro.runtime.failure import FaultPlan

    workdir = Path(tempfile.mkdtemp(prefix="coax_failover_"))
    try:
        ds = make_airline(30_000, seed=7)
        base, pool = ds.data[:25_000], ds.data[25_000:]
        rects = knn_rect_queries(base, 32, 64, seed=1)

        print("== replicated serving under injected faults ==")
        plan = FaultPlan({
            "ship.replica-0": {3: "drop", 7: "tear", 11: "dup"},
            "ship.replica-1": {5: "reorder", 9: ("error", 1)},
        })
        idx = COAXIndex(base, CoaxConfig(auto_compact=False))
        srv = ReplicatedServer(idx, workdir, n_replicas=2, plan=plan)
        for i in range(10):
            srv.insert(pool[i * 120:(i + 1) * 120])
            if i % 3 == 2:
                srv.delete(np.arange(i * 400, i * 400 + 150))
            srv.tick()
        srv.compact()                     # ships the ROTATE control frame
        srv.tick()
        expected = [np.sort(srv.primary.query(r)) for r in rects]
        agree = all(np.array_equal(np.sort(srv.query(r)), expected[i])
                    for i, r in enumerate(rects))
        st = srv.stats()
        lags = {r["name"]: r["lag_frames"] for r in st["replicas"]}
        print(f"  shipped {st['ship']['shipped_frames']} frames "
              f"({st['ship']['shipped_bytes']} B); faults "
              f"{st['transport_faults']}; replica lag {lags}")
        print(f"  routed {st['reads']['replica']} reads to replicas: "
              f"{'bit-identical to primary' if agree else 'MISMATCH'}")
        assert agree and all(v == 0 for v in lags.values())

        print("== primary dies mid-stream; promote ==")
        srv.insert(pool[1200:1400])       # acked, but replicas not yet pumped
        srv.kill_primary()
        acked = srv.acked
        promoted = srv.promote()
        print(f"  promoted {promoted.name}: frontier {promoted.frontier} "
              f">= last ack {acked}; no acknowledged write lost")
        srv.insert(pool[1400:1600])
        srv.delete(np.arange(50))
        srv.tick()
        post = [np.sort(srv.primary.query(r)) for r in rects]
        agree2 = all(np.array_equal(np.sort(srv.query(r)), post[i])
                     for i, r in enumerate(rects))
        st = srv.stats()
        print(f"  serving resumed under {st['primary_dir']}: replicas "
              f"re-seeded, {'answers bit-identical' if agree2 else 'MISMATCH'}"
              f"; promotions={st['promotions']}")
        assert agree2
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def main_durable():
    """Kill-and-resume: journal, crash (torn WAL tail included), recover."""
    import os

    from repro.core import COAXIndex, CoaxConfig
    from repro.data import knn_rect_queries, make_airline
    from repro.engine import QueryServer
    from repro.runtime.failure import GracefulShutdown
    from repro.storage import read_manifest, latest_snapshot, wal_path

    workdir = Path(tempfile.mkdtemp(prefix="coax_durable_"))
    try:
        ds = make_airline(30_000, seed=7)
        base, pool = ds.data[:25_000], ds.data[25_000:]
        rects = knn_rect_queries(base, 48, 64, seed=1)

        print("== process 1: journaled serving ==")
        idx = COAXIndex(base, CoaxConfig(compact_min_delta=2_000,
                                         compact_delta_frac=0.05))
        idx.attach_durability(workdir)
        srv = QueryServer(idx, max_batch=16, checkpoint_every=2)
        first = {}
        for i in range(4):
            srv.insert(pool[i * 200:(i + 1) * 200])
            srv.delete(np.arange(i * 300, i * 300 + 120))
            for r in rects[i * 12:(i + 1) * 12]:
                first[srv.submit(r)] = r
        answers1 = srv.drain()
        s = srv.stats()
        print(f"  served {s['queries']} queries in {s['waves']} waves; "
              f"inserted {s['rows_inserted']}, deleted {s['rows_deleted']}; "
              f"epoch {s['epoch']}, wal_records {s['wal_records']}, "
              f"checkpoints {s['checkpoints_written']}")

        # the durable frontier is here: everything drained + fsynced.  One
        # more write dies mid-append — tear its record as a crash would —
        # so it was never acknowledged and recovery must NOT contain it.
        expected = {qid: idx.query(r) for qid, r in first.items()}
        srv.insert(pool[900:1100]); srv.flush_writes()
        idx.durable.sync()
        wfile = wal_path(workdir, idx.epoch)
        os.truncate(wfile, wfile.stat().st_size - 9)
        del srv, idx
        print("  ...killed (last WAL record torn mid-append)")

        print("== process 2: recover and resume ==")
        t0 = time.time()
        srv2 = QueryServer.recover(workdir, max_batch=16, checkpoint_every=2)
        dt = time.time() - t0
        man = read_manifest(latest_snapshot(workdir))
        print(f"  recovered in {dt*1e3:.0f} ms from snapshot "
              f"epoch={man['epoch']} wal_seq={man['wal_seq']} "
              f"+ WAL replay; n_rows={srv2.executor.index.n_rows}")
        qids = {srv2.submit(r): qid for qid, r in first.items()}
        answers2 = srv2.drain()
        agree = all(np.array_equal(answers2[q2], expected[q1])
                    for q2, q1 in qids.items())
        print(f"  re-answered {len(qids)} queries: "
              f"{'bit-identical to pre-crash index' if agree else 'MISMATCH'}")
        assert agree
        srv2.insert(pool[1100:1300]); srv2.flush_writes()
        srv2.executor.index.durable.sync()
        print(f"  resumed journaling: "
              f"{srv2.stats()['wal_records']} records in the live WAL")

        print("== process 2: SIGTERM -> graceful shutdown ==")
        with GracefulShutdown() as stop:
            srv2.shutdown = stop
            for r in rects:
                srv2.submit(r)
            srv2.insert(pool[1300:1400])
            partial = srv2.drain(max_waves=1)   # mid-stream...
            stop.request()                      # ...the preemption notice lands
            partial.update(srv2.drain())        # finishes in-flight, forms no more
            srv2.close()                        # flush writes + fsync + release WAL
        s2 = srv2.stats()
        print(f"  answered {len(partial)} before the flag; {s2['pending']} "
              f"queries left for the next incarnation; writes flushed "
              f"(pending={s2['writes_pending']}), WAL synced, "
              f"closed={s2['closed']}")
        assert s2["writes_pending"] == 0 and s2["closed"]
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def main():
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.runtime.serve_loop import ServeConfig, Server

    cfg = dataclasses.replace(
        get_config("h2o-danube-3-4b"),
        n_layers=4, d_model=256, d_ff=768, vocab_size=8192,
        n_heads=8, n_kv_heads=4, head_dim=32, window=256)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    srv = Server(model, params,
                 ServeConfig(batch_size=8, max_new_tokens=24, cache_len=512,
                             eos_token=0))

    rng = np.random.default_rng(7)
    n_requests = 48
    for i in range(n_requests):
        plen = int(rng.choice([16, 24, 48, 96, 192]))
        srv.submit(rng.integers(1, 8000, plen).astype(np.int32),
                   max_new_tokens=int(rng.integers(8, 24)),
                   priority=float(rng.random()))
    print(f"submitted {n_requests} requests; router stats: {srv.router.stats()}")

    t0 = time.time()
    results = srv.run_until_drained()
    dt = time.time() - t0
    toks = sum(r.tokens.size for r in results)
    print(f"served {len(results)} requests in {srv.waves} waves, "
          f"{toks} tokens in {dt:.1f}s ({toks/dt:.0f} tok/s on CPU)")
    by_wave = {}
    for r in results:
        by_wave.setdefault(r.wave, []).append(r.prompt_len)
    for w, lens in sorted(by_wave.items()):
        print(f"  wave {w}: {len(lens)} reqs, prompt lens {sorted(lens)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--durable", action="store_true",
                    help="kill-and-resume durability demo (DESIGN.md §7)")
    ap.add_argument("--failover", action="store_true",
                    help="replicated failover demo (DESIGN.md §8)")
    args = ap.parse_args()
    if args.failover:
        main_failover()
    elif args.durable:
        main_durable()
    else:
        main()
