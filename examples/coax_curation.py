"""Data-curation driver: COAX as the metadata index of a training data plane.

    PYTHONPATH=src python examples/coax_curation.py

Builds a document corpus whose metadata columns carry soft FDs
(token_len ~ byte_len ~ compute_cost, doc_id ~ timestamp), indexes them with
COAX, and resolves a staged curriculum through range queries — comparing
latency and exactness against a full scan.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.data.curation import CuratedSelector, MetaQuery
from repro.data.pipeline import make_corpus


def main():
    corpus = make_corpus(200_000, seed=0)
    sel = CuratedSelector(corpus)
    d = sel.describe()
    print(f"corpus: {d['n_rows']:,} docs, meta cols {d['meta_cols']}")
    print(f"COAX detected groups: "
          f"{[(g['predictor'], g['dependents']) for g in d['groups']]}")
    print(f"indexed dims {d['indexed_dims']}; directory "
          f"{d['memory_footprint_bytes']/1024:.0f} KiB; "
          f"build {d['build_time_s']*1e3:.0f} ms")

    curriculum = [
        MetaQuery(token_len=(64, 512), quality=(0.6, 1.1)),      # stage 0: short
        MetaQuery(token_len=(512, 4096), quality=(0.6, 1.1)),    # stage 1: medium
        MetaQuery(token_len=(4096, 32768), quality=(0.7, 1.1)),  # stage 2: long
    ]
    for i, q in enumerate(curriculum):
        t0 = time.perf_counter()
        got = sel.select(q)
        t_coax = time.perf_counter() - t0
        t0 = time.perf_counter()
        want = sel.select_reference(q)
        t_scan = time.perf_counter() - t0
        assert np.array_equal(got, want)
        print(f"stage {i}: {got.size:,} docs | COAX {t_coax*1e3:.2f} ms vs "
              f"scan {t_scan*1e3:.2f} ms ({t_scan/t_coax:.1f}x) — exact")


if __name__ == "__main__":
    main()
