"""Quickstart: build a COAX index on correlated multidimensional data and
run exact range queries through the soft-FD translation path.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import COAXIndex, FullScan
from repro.data import knn_rect_queries, make_airline


def main():
    # 1. An airline-like dataset: (Distance -> TimeElapsed, AirTime) and
    #    (DepTime -> ArrTime, SchedArrTime) are soft functional dependencies.
    ds = make_airline(500_000, seed=0)
    print(f"dataset: {ds.data.shape[0]:,} rows x {ds.data.shape[1]} attrs")

    # 2. Build: COAX detects the FDs, learns linear models with error margins,
    #    splits inliers/outliers, and indexes ONLY the predictor dims.
    t0 = time.time()
    index = COAXIndex(ds.data)
    print(f"built in {time.time() - t0:.2f}s")
    d = index.describe()
    for g in d["groups"]:
        print(f"  soft FD: attr {g['predictor']} -> {g['dependents']}")
    print(f"  indexed dims: {d['indexed_dims']} (of {ds.data.shape[1]});"
          f" primary ratio: {d['primary_ratio']:.1%};"
          f" directory: {d['memory_footprint_bytes']/1024:.0f} KiB")

    # 3. Query: rectangles over ALL dims; constraints on dependent attrs are
    #    translated onto the indexed attrs (Eq. 2).  Results are exact.
    rects = knn_rect_queries(ds.data, 10, 200, seed=1, sample_cap=50_000)
    ref = FullScan(ds.data)
    t0 = time.time()
    for r in rects:
        hits = index.query(r)
    coax_ms = (time.time() - t0) / len(rects) * 1e3
    t0 = time.time()
    for r in rects:
        truth = ref.query(r)
    scan_ms = (time.time() - t0) / len(rects) * 1e3
    assert np.array_equal(hits, truth), "COAX must return the exact result set"
    print(f"query: COAX {coax_ms:.2f} ms vs full scan {scan_ms:.2f} ms "
          f"({scan_ms / coax_ms:.0f}x) — exact results verified")


if __name__ == "__main__":
    main()
