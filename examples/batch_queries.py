"""Batched query engine quickstart (DESIGN.md §2, §5).

    PYTHONPATH=src python examples/batch_queries.py

Builds a COAX index over airline-like data, submits a mixed-priority range
query stream to the QueryServer, drains it in fused waves, and compares
engine throughput against the per-query loop.  Then goes live: inserts and
deletes are admitted next to queries (applied at wave boundaries), answered
from the delta plane, and folded back in by a compaction.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import COAXIndex
from repro.data import knn_rect_queries, make_airline
from repro.engine import QueryServer


def main():
    ds = make_airline(100_000, seed=0)
    idx = COAXIndex(ds.data)
    print(f"built COAX over {ds.data.shape}: "
          f"{len(idx.groups)} FD groups, primary ratio {idx.primary_ratio:.2f}")

    rects = knn_rect_queries(ds.data, 192, 64, seed=1, sample_cap=50_000)
    srv = QueryServer(idx, max_batch=64)
    rng = np.random.default_rng(2)
    qids = [srv.submit(r, priority=float(rng.integers(0, 3))) for r in rects]
    print(f"submitted {len(qids)} range queries; pending={len(srv)}")

    t0 = time.perf_counter()
    results = srv.drain()
    batch_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    loop = [idx.query(r) for r in rects]
    loop_s = time.perf_counter() - t0

    assert all(np.array_equal(results[q], l) for q, l in zip(qids, loop))
    s = srv.stats()
    print(f"drained {s['queries']} queries in {s['waves_drained']} waves: "
          f"{len(rects)/batch_s:.0f} QPS batched vs {len(rects)/loop_s:.0f} QPS "
          f"looped ({loop_s/batch_s:.2f}x)")
    total_hits = sum(r.size for r in results.values())
    print(f"total hits {total_hits}, index directory "
          f"{idx.memory_footprint()/1024:.1f} KiB")

    # --- the write path (DESIGN.md §5) -------------------------------- #
    fresh = make_airline(2_000, seed=7).data
    w_ins = srv.insert(fresh)                       # queued ...
    w_del = srv.delete(rng.choice(100_000, 500, replace=False))
    qid = srv.submit(rects[0])
    res = srv.drain()                               # ... applied at the wave
    new_ids = srv.write_results[w_ins]
    print(f"inserted {new_ids.size} rows / deleted {srv.write_results[w_del]}; "
          f"delta={idx.delta_rows} tombstones={idx.tombstone_count} "
          f"epoch={idx.epoch}")
    assert np.array_equal(res[qid], idx.query(rects[0]))
    idx.compact()
    print(f"compacted -> epoch {idx.epoch}, {idx.n_rows} live rows, "
          f"delta={idx.delta_rows}, drift predictability "
          f"{idx.drift_predictability():.3f}")
    assert np.array_equal(res[qid], idx.query(rects[0]))  # answers survive


if __name__ == "__main__":
    main()
