"""End-to-end training driver: COAX-curated data -> sharded loader ->
fault-tolerant train loop with checkpointing.

    PYTHONPATH=src python examples/train_lm.py                  # quick preset
    PYTHONPATH=src python examples/train_lm.py --preset 130m --steps 300

The quick preset (default) trains a ~10M-param danube-style model for 200
steps in a few minutes on CPU; ``--preset 130m`` selects the full
mamba2-130m assigned config (a ~100M-class model) — same code path, more
compute.  On a real cluster the identical script runs under
launch/mesh.make_production_mesh with the dry-run's shardings.
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.data.curation import CuratedSelector, MetaQuery
from repro.data.pipeline import ShardedLoader, make_corpus
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime.train_loop import TrainLoopConfig, train


def make_model(preset: str):
    if preset == "130m":
        return build_model(get_config("mamba2-130m"))
    cfg = dataclasses.replace(
        get_config("h2o-danube-3-4b"),
        n_layers=4, d_model=256, d_ff=768, vocab_size=8192,
        n_heads=8, n_kv_heads=4, head_dim=32, window=256)
    return build_model(cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["quick", "130m"], default="quick")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    model = make_model(args.preset)
    vocab = model.cfg.padded_vocab
    print(f"model: {model.cfg.name} ({model.param_count()/1e6:.1f}M params)")

    # COAX-curated corpus: select mid-length, high-quality documents through
    # the paper's index (the data-plane integration, DESIGN.md §2).
    corpus = make_corpus(30_000, vocab_size=min(vocab, 32_000), seed=0)
    sel = CuratedSelector(corpus)
    docs = sel.select(MetaQuery(token_len=(256, 8192), quality=(0.5, 1.1)))
    print(f"curation: {docs.size:,}/{corpus.meta.shape[0]:,} docs selected "
          f"via COAX ({sel.build_time*1e3:.0f} ms build)")

    loader = ShardedLoader(corpus, batch_size=args.batch, seq_len=args.seq,
                           doc_ids=docs, seed=1)
    out = train(
        model, iter(loader), AdamWConfig(lr=1e-3),
        TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=50, log_every=10, warmup=20))
    loader.close()
    print(f"done: {out['final_step']} steps, final loss "
          f"{out['history'][-1]['loss']:.4f}, restarts={out['restarts']}, "
          f"stragglers={len(out['stragglers'])}")


if __name__ == "__main__":
    main()
