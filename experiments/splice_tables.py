"""Regenerate the §Roofline table inside EXPERIMENTS.md from the dry-run
JSONs (idempotent: replaces the block between the table header and the
'Reading the table' marker)."""
import io
import re
import sys
from contextlib import redirect_stdout
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.argv = ["report", "--mesh", "single"]
from repro.launch import report  # noqa: E402

buf = io.StringIO()
with redirect_stdout(buf):
    report.main()
tbl = buf.getvalue().strip()

md_path = Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"
md = md_path.read_text()
start = md.index("| arch | shape | mesh |")
end = md.index("Reading the table:")
md = md[:start] + tbl + "\n\n" + md[end:]
md_path.write_text(md)
print("spliced", len(tbl.splitlines()), "table lines")
