# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: runs every paper-figure benchmark plus the framework
benchmarks.  ``--quick`` shrinks datasets for CI-scale runs; the defaults
match configs/paper_coax.py (2M-row generators standing in for the paper's
80M/105M, scaled for a CPU container — pass --rows to go bigger)."""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced rows/queries for smoke runs")
    ap.add_argument("--rows", type=int, default=None)
    args = ap.parse_args()

    from . import (bench_framework, bench_kernels, bench_memory,
                   bench_queries, bench_selectivity, bench_theory)

    rows = args.rows or (200_000 if args.quick else None)
    nq = 40 if args.quick else None

    print("name,us_per_call,derived")
    bench_queries.run(rows=rows, n_queries=nq)
    bench_selectivity.run(rows=(rows or None), n_queries=(20 if args.quick else 60))
    bench_memory.run(rows=rows, n_queries=(20 if args.quick else 80))
    bench_memory.table1(rows=rows)
    bench_theory.run()
    bench_kernels.run(n=100_000 if args.quick else 1_000_000)
    bench_framework.run()


if __name__ == "__main__":
    main()
