"""§7 theory validation: Eq. 5 effectiveness, Thm 7.1 MET, Thm 7.3 variance,
Thm 7.4 segment counts — closed forms vs Monte-Carlo simulation."""
from __future__ import annotations

import numpy as np

from .common import emit
from repro.core import theory


def run() -> dict:
    out = {}
    # Eq. 5: effectiveness vs margin width
    for eps in (0.1, 0.5, 1.0, 2.0, 5.0):
        e = theory.effectiveness(q_y=5.0, eps=eps)
        out[("eff", eps)] = e
        emit(f"theory/eq5/eps={eps}", e * 100, "% effectiveness (q_y=5)")

    # Thm 7.1 / 7.3: MET mean + variance vs simulation
    for eps, sigma in ((10.0, 1.0), (20.0, 1.0), (8.0, 0.5)):
        mean, var = theory.simulate_met(eps, sigma, trials=1_500, seed=11)
        t_mean = theory.met_expectation(eps, sigma)
        t_var = theory.met_variance(eps, sigma)
        out[("met", eps, sigma)] = (mean, t_mean, var, t_var)
        emit(f"theory/thm7.1/eps={eps},sigma={sigma}", mean,
             f"theory={t_mean:.0f} rel_err={(mean - t_mean) / t_mean:+.2%}")
        emit(f"theory/thm7.3/eps={eps},sigma={sigma}", var,
             f"theory={t_var:.0f} rel_err={(var - t_var) / t_var:+.2%}")

    # Thm 7.2: slope = mu maximises coverage
    best = theory.met_drifted_expectation(8.0, 1.0, 0.0)
    off = theory.met_drifted_expectation(8.0, 1.0, 0.3)
    emit("theory/thm7.2/drift_penalty", best / off, "x coverage at optimal slope")

    # Thm 7.4: segments to cover a stream
    rng = np.random.default_rng(13)
    n, sigma, eps = 300_000, 1.0, 12.0
    gaps = rng.normal(5.0, sigma, n)
    segs = theory.greedy_segment_count(gaps, eps)
    t_segs = theory.expected_segments(n, eps, sigma)
    out["segments"] = (segs, t_segs)
    emit("theory/thm7.4/segments", segs, f"theory={t_segs:.0f} n={n}")
    return out


if __name__ == "__main__":
    run()
