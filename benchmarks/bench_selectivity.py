"""Fig. 7: range-query runtime vs selectivity (airline year-2008 slice).

Selectivity is driven by the KNN neighbourhood size K (paper §8.1.2): the
paper sweeps range queries of growing result size on the 7M-row 2008 slice.
"""
from __future__ import annotations

import numpy as np

from .common import PCFG, build_engines, dataset, emit, queries, time_queries


def run(rows: int = None, n_queries: int = 60) -> dict:
    rows = rows or PCFG.airline_2008_rows
    ds = dataset("airline2008", rows)
    engines = build_engines(ds.data)
    out = {}
    for k in PCFG.selectivities:
        rects = queries("airline2008", rows, n_queries, k, seed=PCFG.seed + k)
        for name, (eng, _) in engines.items():
            us, n_res = time_queries(eng, rects)
            sel = n_res / (n_queries * rows)
            out[(k, name)] = {"us": us, "selectivity": sel}
            emit(f"fig7/k={k}/{name}", us, f"selectivity={sel:.5f}")
    return out


if __name__ == "__main__":
    run()
