"""Framework-side benchmarks: tiny-model train/serve throughput on CPU, the
COAX-vs-linear-scan router comparison, and the dry-run roofline summary."""
from __future__ import annotations

import dataclasses
import json
import time
from glob import glob
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit
from repro.configs import get_config
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.router import CoaxRouter
from repro.runtime.steps import make_train_step

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _tiny(arch="h2o-danube-3-4b"):
    cfg = get_config(arch)
    return dataclasses.replace(cfg, n_layers=4, d_model=256, d_ff=512,
                               vocab_size=2048, n_heads=8, n_kv_heads=4,
                               head_dim=32, window=128)


def train_throughput(steps: int = 10, batch: int = 4, seq: int = 256) -> float:
    cfg = _tiny()
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, 2048, (batch, seq)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 2048, (batch, seq)), jnp.int32)}
    params, opt, _ = step(params, opt, b)  # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        params, opt, m = step(params, opt, b)
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    tps = steps * batch * seq / dt
    emit("framework/train_tokens_per_s", dt / steps * 1e6,
         f"tokens/s={tps:.0f} ({cfg.n_layers}L d{cfg.d_model} CPU)")
    return tps


def decode_throughput(steps: int = 20, batch: int = 8) -> float:
    cfg = _tiny()
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    cache = model.init_cache(batch, 512)
    decode = jax.jit(model.decode_step)
    tok = jnp.ones((batch, 1), jnp.int32)
    logits, cache = decode(params, cache, tok, jnp.int32(0))  # compile
    t0 = time.perf_counter()
    for i in range(steps):
        logits, cache = decode(params, cache, tok, jnp.int32(i + 1))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    emit("framework/decode_us_per_token", dt / (steps * batch) * 1e6,
         f"batch={batch}")
    return steps * batch / dt


def router_comparison(n_requests: int = 4_096, n_admits: int = 40) -> dict:
    """COAX-indexed admission vs a linear scan of the pool."""
    rng = np.random.default_rng(3)
    prompts = [np.ones(int(rng.integers(8, 4096)), np.int32)
               for _ in range(n_requests)]

    router = CoaxRouter(rebuild_threshold=n_requests)
    for i, p in enumerate(prompts):
        router.submit(p, 128, priority=float(rng.random()), arrival=float(i))
    router._rebuild()
    t0 = time.perf_counter()
    got = 0
    for j in range(n_admits):
        lo = 64 * (j % 8)
        got += len(router.admit(8, prompt_len_range=(lo, lo + 512)))
    t_coax = (time.perf_counter() - t0) / n_admits * 1e6

    # linear-scan reference
    pool = [(float(i), len(p), float(rng.random())) for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    for j in range(n_admits):
        lo = 64 * (j % 8)
        hits = [r for r in pool if lo <= r[1] < lo + 512]
        hits.sort(key=lambda r: (-r[2], r[0]))
        hits = hits[:8]
    t_scan = (time.perf_counter() - t0) / n_admits * 1e6

    emit("framework/router_admit_coax", t_coax, f"pool={n_requests} admitted={got}")
    emit("framework/router_admit_linear_scan", t_scan, f"pool={n_requests}")
    return {"coax_us": t_coax, "scan_us": t_scan}


def roofline_summary() -> dict:
    """Aggregate the dry-run cells into the §Roofline summary rows."""
    cells = {}
    for f in sorted(glob(str(DRYRUN_DIR / "*baseline.json"))):
        d = json.loads(Path(f).read_text())
        key = (d["arch"], d["shape"], d["mesh"])
        cells[key] = d
    ok = [d for d in cells.values() if d.get("status") == "ok"]
    if not ok:
        emit("framework/dryrun_cells", 0, "no dry-run results found")
        return {}
    fits = sum(1 for d in ok
               if d["memory"]["peak_bytes_per_device"] <= 16 * 2**30)
    emit("framework/dryrun_cells_ok", len(ok),
         f"skipped={len(cells) - len(ok)} fit_hbm={fits}")
    for d in ok:
        if d["mesh"] != "single":
            continue
        r = d["roofline"]
        emit(f"roofline/{d['arch']}/{d['shape']}",
             r["step_time_bound_s"] * 1e6,
             f"dom={r['dominant']},mfu_bound={d.get('roofline_mfu_bound', 0) or 0:.3f},"
             f"mem_gib={d['memory']['peak_bytes_per_device']/2**30:.1f}")
    return cells


def run() -> None:
    train_throughput()
    decode_throughput()
    router_comparison()
    roofline_summary()


if __name__ == "__main__":
    run()
