"""Shared benchmark machinery: timed query loops, dataset cache, CSV rows."""
from __future__ import annotations

import functools
import sys
import time
from pathlib import Path

import numpy as np

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.configs.paper_coax import CONFIG as PCFG  # noqa: E402
from repro.core import COAXIndex, ColumnFiles, FullScan, STRTree, UniformGrid  # noqa: E402
from repro.data import knn_rect_queries, make_airline, make_osm  # noqa: E402

ROWS = []  # (name, us_per_call, derived)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


@functools.lru_cache(maxsize=None)
def dataset(name: str, rows: int, seed: int = PCFG.seed):
    if name == "airline":
        return make_airline(rows, seed=seed)
    if name == "airline2008":
        return make_airline(rows, seed=seed + 13)
    if name == "osm":
        return make_osm(rows, seed=seed)
    raise KeyError(name)


@functools.lru_cache(maxsize=None)
def queries(ds_name: str, rows: int, n: int, k: int, seed: int = PCFG.seed):
    ds = dataset(ds_name, rows)
    q = knn_rect_queries(ds.data, n, k, seed=seed, sample_cap=100_000)
    q.setflags(write=False)
    return q


def build_engines(data: np.ndarray, which=("coax", "uniform_grid",
                                           "column_files", "r_tree", "full_scan")):
    out = {}
    for w in which:
        t0 = time.time()
        if w == "coax":
            out[w] = (COAXIndex(data), time.time() - t0)
        elif w == "uniform_grid":
            out[w] = (UniformGrid(data), time.time() - t0)
        elif w == "column_files":
            out[w] = (ColumnFiles(data), time.time() - t0)
        elif w == "r_tree":
            out[w] = (STRTree(data, node_cap=PCFG.rtree_node_cap), time.time() - t0)
        elif w == "full_scan":
            out[w] = (FullScan(data), time.time() - t0)
    return out


def time_queries(engine, rects, repeats: int = 1):
    """Returns (us_per_query, total_results)."""
    total = 0
    t0 = time.perf_counter()
    for _ in range(repeats):
        for r in rects:
            total += engine.query(r).size
    dt = time.perf_counter() - t0
    return dt / (len(rects) * repeats) * 1e6, total // repeats
