"""Fig. 6: point + range query runtime, COAX vs R-Tree / uniform grid /
column files / full scan, on airline-like and OSM-like data.

Per the paper's methodology (§8.2.1: 'We use the configuration that performs
best for each index'), every engine's resolution knob is tuned on a held-out
query subset before measurement.
"""
from __future__ import annotations

import numpy as np

from .common import PCFG, dataset, emit, queries, time_queries
from repro.core import (COAXIndex, CoaxConfig, ColumnFiles, FullScan, STRTree,
                        UniformGrid, point_rect)

SWEEPS = {
    "coax": [8, 16, 32, 64],
    "uniform_grid": [3, 4, 6, 8, 12],
    "column_files": [3, 4, 6, 8, 12],
    "r_tree": [6, 10, 16],
}


def _build(name, data, knob):
    if name == "coax":
        return COAXIndex(data, CoaxConfig(primary_cells_per_dim=knob))
    if name == "uniform_grid":
        return UniformGrid(data, cells_per_dim=knob)
    if name == "column_files":
        return ColumnFiles(data, cells_per_dim=knob)
    if name == "r_tree":
        return STRTree(data, leaf_cap=knob, node_cap=knob)
    return FullScan(data)


def tuned_engine(name, data, tune_rects):
    """Pick the best-latency knob on the tuning subset (paper §8.2.1)."""
    if name == "full_scan":
        return FullScan(data), None
    best = None
    for knob in SWEEPS[name]:
        eng = _build(name, data, knob)
        us, _ = time_queries(eng, tune_rects)
        if best is None or us < best[1]:
            best = (eng, us, knob)
    return best[0], best[2]


def run(rows: int = None, n_queries: int = None) -> dict:
    rows = rows or PCFG.airline_rows
    n_q = n_queries or PCFG.n_queries
    out = {}
    for ds_name, ds_rows in (("airline", rows), ("osm", rows)):
        ds = dataset(ds_name, ds_rows)
        rects = queries(ds_name, ds_rows, n_q, PCFG.knn_k)
        tune = rects[: max(8, n_q // 8)]
        measure = rects[max(8, n_q // 8):]
        rng = np.random.default_rng(PCFG.seed)
        pts = ds.data[rng.choice(ds.data.shape[0], n_q, replace=False)]
        point_rects = np.stack([point_rect(p) for p in pts])

        for name in ("coax", "uniform_grid", "column_files", "r_tree", "full_scan"):
            eng, knob = tuned_engine(name, ds.data, tune)
            us_r, n_res = time_queries(eng, measure)
            us_p, _ = time_queries(eng, point_rects)
            out[(ds_name, name)] = {"range_us": us_r, "point_us": us_p,
                                    "knob": knob, "results": int(n_res)}
            emit(f"fig6/{ds_name}/{name}/range", us_r, f"results={n_res},knob={knob}")
            emit(f"fig6/{ds_name}/{name}/point", us_p, f"knob={knob}")

        best_rival = min(out[(ds_name, n)]["range_us"] for n in
                         ("uniform_grid", "column_files", "r_tree"))
        speedup = best_rival / out[(ds_name, "coax")]["range_us"]
        emit(f"fig6/{ds_name}/coax_speedup_vs_best_rival", speedup,
             "x faster (paper: ~1.25x)")
    return out


if __name__ == "__main__":
    run()
