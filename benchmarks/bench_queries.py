"""Fig. 6: point + range query runtime, COAX vs R-Tree / uniform grid /
column files / full scan, on airline-like and OSM-like data.

Per the paper's methodology (§8.2.1: 'We use the configuration that performs
best for each index'), every engine's resolution knob is tuned on a held-out
query subset before measurement.

``--batch`` switches to the throughput mode (DESIGN.md §2): QPS of the
batched engine (``COAXIndex.query_batch`` through ``BatchQueryExecutor``)
vs the per-query loop across batch sizes, emitted to ``BENCH_queries.json``.
``--backend {numpy,device,both}`` additionally sweeps the device-resident
serving plane (DESIGN.md §4) over the same waves — the ``device_qps``
section — asserting both backends return identical hits before timing.
``--mixed`` drives the mutable lifecycle (DESIGN.md §5): a ``QueryServer``
interleaving query waves with insert/delete admissions at a sweep of write
ratios (FD-violating insert bursts included, so compaction and drift
relearns fire), emitted to ``BENCH_updates.json``.
``--shards K[,K...]`` sweeps the scatter-gather plane (DESIGN.md §6): a
``ShardedCOAX`` per shard count, range-partitioned, served through the
executor's sharded mode — per-K QPS, pruning rate and per-shard work merge
into the ``sharded`` section of ``BENCH_queries.json``.
``--recover`` drives the durability plane (DESIGN.md §7): snapshot size
and save latency, then recovery time as a function of WAL length (the
replay tail), emitted to ``BENCH_storage.json``.
``--cache`` drives the semantic result cache (DESIGN.md §9): a Zipfian
hot-rect sweep (cached vs uncached QPS, bit-identity gated) plus the
pinned-epoch MVCC drill, emitted to the ``cache`` section of
``BENCH_queries.json``.  Every mode owns ONE top-level section of its
BENCH file and merge-preserves the others.
``--smoke`` shrinks the sweep and turns the throughput/agreement checks
into hard assertions for CI — for ``--mixed`` the gate is hit agreement
between the mutated index and a rebuild-from-scratch oracle, for
``--shards`` it is cross-shard vs single-index hit agreement, for
``--recover`` it is recovered-vs-live hit agreement at every WAL length.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from .common import PCFG, dataset, emit, queries, time_queries
from repro.core import (COAXIndex, CoaxConfig, ColumnFiles, FullScan, STRTree,
                        UniformGrid, point_rect)
from repro.data import knn_rect_queries
from repro.engine import BatchQueryExecutor

SWEEPS = {
    "coax": [8, 16, 32, 64],
    "uniform_grid": [3, 4, 6, 8, 12],
    "column_files": [3, 4, 6, 8, 12],
    "r_tree": [6, 10, 16],
}


def _read_bench_json(path: Path) -> dict:
    """Existing benchmark doc at ``path``, or {} (missing/corrupt) — so
    every mode can preserve the other modes' sections."""
    try:
        return json.loads(path.read_text())
    except (OSError, ValueError):
        return {}


def _write_bench_section(out_path, default_name: str, section: str,
                         result: dict) -> Path:
    """Merge ``result`` under the ``section`` key of a shared BENCH file,
    preserving EVERY foreign top-level key.

    All writers of a shared file go through here: each mode owns exactly
    one top-level section and never sees the others.  (run_batch used to
    hand-preserve only "sharded" and run_mixed overwrote BENCH_updates.json
    wholesale, so any other section — including the cache sweep — was
    silently clobbered by a re-run of a sibling mode.)
    """
    out = Path(out_path) if out_path else \
        Path(__file__).resolve().parents[1] / default_name
    merged = _read_bench_json(out)
    merged[section] = result
    out.write_text(json.dumps(merged, indent=2) + "\n")
    return out


def _build(name, data, knob):
    if name == "coax":
        return COAXIndex(data, CoaxConfig(primary_cells_per_dim=knob))
    if name == "uniform_grid":
        return UniformGrid(data, cells_per_dim=knob)
    if name == "column_files":
        return ColumnFiles(data, cells_per_dim=knob)
    if name == "r_tree":
        return STRTree(data, leaf_cap=knob, node_cap=knob)
    return FullScan(data)


def tuned_engine(name, data, tune_rects):
    """Pick the best-latency knob on the tuning subset (paper §8.2.1)."""
    if name == "full_scan":
        return FullScan(data), None
    best = None
    for knob in SWEEPS[name]:
        eng = _build(name, data, knob)
        us, _ = time_queries(eng, tune_rects)
        if best is None or us < best[1]:
            best = (eng, us, knob)
    return best[0], best[2]


def run(rows: int = None, n_queries: int = None) -> dict:
    rows = rows or PCFG.airline_rows
    n_q = n_queries or PCFG.n_queries
    out = {}
    for ds_name, ds_rows in (("airline", rows), ("osm", rows)):
        ds = dataset(ds_name, ds_rows)
        rects = queries(ds_name, ds_rows, n_q, PCFG.knn_k)
        tune = rects[: max(8, n_q // 8)]
        measure = rects[max(8, n_q // 8):]
        rng = np.random.default_rng(PCFG.seed)
        pts = ds.data[rng.choice(ds.data.shape[0], n_q, replace=False)]
        point_rects = np.stack([point_rect(p) for p in pts])

        for name in ("coax", "uniform_grid", "column_files", "r_tree", "full_scan"):
            eng, knob = tuned_engine(name, ds.data, tune)
            us_r, n_res = time_queries(eng, measure)
            us_p, _ = time_queries(eng, point_rects)
            out[(ds_name, name)] = {"range_us": us_r, "point_us": us_p,
                                    "knob": knob, "results": int(n_res)}
            emit(f"fig6/{ds_name}/{name}/range", us_r, f"results={n_res},knob={knob}")
            emit(f"fig6/{ds_name}/{name}/point", us_p, f"knob={knob}")

        best_rival = min(out[(ds_name, n)]["range_us"] for n in
                         ("uniform_grid", "column_files", "r_tree"))
        speedup = best_rival / out[(ds_name, "coax")]["range_us"]
        emit(f"fig6/{ds_name}/coax_speedup_vs_best_rival", speedup,
             "x faster (paper: ~1.25x)")
    return out


def run_batch(rows: int = 100_000, n_queries: int = 256,
              batch_sizes=(1, 8, 16, 64, 256),
              out_path: str = None, backend: str = "both",
              smoke: bool = False) -> dict:
    """Throughput mode: QPS vs wave width, batched engine vs per-query loop.

    Both paths answer the same rects on the same index; per-wave results are
    checked for set equality against the loop before timing is reported.
    ``backend`` sweeps the numpy path, the device-resident plan (DESIGN.md
    §4), or both.  Each sweep point also records p50/p99 wave latency
    (submit→drain, so the device pipeline's overlap shows up in QPS but not
    in per-wave latency) and the device sweep records the plan's rollups
    (compile cache size, kernel dispatches, transfer bytes both ways).

    ``smoke`` turns the sweep into the CI gate: batch QPS beats the
    per-query loop, all backends agree on hit counts, every non-fallback
    device wave is exactly ONE fused kernel dispatch, and — on a real
    accelerator only — ``device_speedup > 1`` at batch ≥ 64 (CPU interpret
    mode is a correctness harness, not a fast path, so the speedup gate is
    skipped there).
    """
    if smoke:
        batch_sizes = tuple(bs for bs in batch_sizes if bs <= 64) or (1, 64)
    ds = dataset("airline", rows)
    rects = np.asarray(queries("airline", rows, n_queries, PCFG.knn_k))
    idx = COAXIndex(ds.data)

    # per-query loop baseline (the seed's only path)
    t0 = time.perf_counter()
    loop_hits = [idx.query(r) for r in rects]
    single_s = time.perf_counter() - t0
    single_qps = len(rects) / single_s
    emit("batch/airline/per_query_loop_qps", single_qps,
         f"rows={rows},queries={len(rects)}")

    result = {
        "dataset": "airline", "rows": rows, "n_queries": len(rects),
        "single_qps": single_qps, "batch_qps": {}, "speedup": {},
        "wave_latency_ms": {},
    }
    backends = ("numpy", "device") if backend == "both" else (backend,)
    hit_counts = {}
    for bk in backends:
        if bk == "device":
            from repro.engine import device_available
            if not device_available():
                emit("batch/airline/device", 0.0, "skipped: jax unavailable")
                continue
            result["device_qps"] = {}
            result["device_speedup"] = {}
        qps_key = "batch_qps" if bk == "numpy" else "device_qps"
        spd_key = "speedup" if bk == "numpy" else "device_speedup"
        result["wave_latency_ms"][bk] = {}
        for bs in batch_sizes:
            ex = BatchQueryExecutor(idx, max_batch=bs, backend=bk)
            got = ex.execute(rects)      # warm + compile + correctness pass
            assert all(np.array_equal(g, w)
                       for g, w in zip(got, loop_hits)), (bk, bs)
            ex.reset_stats()
            dev0 = idx.device_stats() if bk == "device" else None
            t0 = time.perf_counter()
            ex.execute(rects)
            dt = time.perf_counter() - t0
            qps = len(rects) / dt
            result[qps_key][bs] = qps
            result[spd_key][bs] = qps / single_qps
            s = ex.stats()
            hit_counts[(bk, bs)] = s["hits"]
            result["wave_latency_ms"][bk][bs] = {
                "p50": s["wave_p50_ms"], "p99": s["wave_p99_ms"]}
            if bk == "device" and dev0 is not None:
                # §4 gate: one fused kernel launch per non-fallback wave
                disp = idx.device_stats()["dispatches"] - dev0["dispatches"]
                assert disp == s["waves"] - s["fallback_waves"], (
                    f"{disp} dispatches for {s['waves']} waves "
                    f"({s['fallback_waves']} fallbacks) at batch={bs}")
            emit(f"batch/airline/{bk}_qps@{bs}", qps,
                 f"speedup={qps / single_qps:.2f}x,"
                 f"p50={s['wave_p50_ms']:.2f}ms,p99={s['wave_p99_ms']:.2f}ms,"
                 f"rows_scanned={s['rows_scanned']},"
                 f"cells_probed={s['cells_probed']},"
                 f"fallbacks={s['device_fallbacks']},"
                 f"hit_overflows={s['hit_overflows']}")
        if bk == "device":
            dstats = idx.device_stats()
            result["device_stats"] = dstats      # compile_count + transfers
            emit("batch/airline/device_plan", float(dstats["dispatches"]),
                 f"compile_count={dstats['compile_count']},"
                 f"bytes_h2d={dstats['bytes_h2d']},"
                 f"bytes_d2h={dstats['bytes_d2h']}")
    idx.backend = "numpy"

    if smoke:
        # the throughput gate is numpy-batch vs per-query loop; a device-only
        # sweep on CPU legitimately trails the loop (the device plane targets
        # real accelerators), so only gate when the numpy sweep ran
        if result["batch_qps"]:
            best_batch = max(result["batch_qps"].values())
            assert best_batch >= single_qps, (
                f"batch path regressed: {best_batch:.0f} qps < per-query "
                f"loop {single_qps:.0f} qps")
        assert hit_counts, "smoke ran no backend sweep (jax unavailable?)"
        counts = set(hit_counts.values())
        assert len(counts) == 1, f"backends disagree on hit counts: {hit_counts}"
        if result.get("device_speedup"):
            import jax
            if jax.default_backend() != "cpu":   # real accelerator only
                best_dev = max(v for b, v in result["device_speedup"].items()
                               if b >= 64)
                assert best_dev > 1.0, (
                    f"device plane slower than per-query loop on "
                    f"{jax.default_backend()}: {best_dev:.2f}x at batch>=64")
        emit("batch/airline/smoke", 1.0,
             f"batch>=single ok, hit counts agree ({counts.pop()}), "
             f"one dispatch per device wave")

    _write_bench_section(out_path, "BENCH_queries.json", "batch", result)
    print(f"BENCH {json.dumps(result)}")
    return result


def run_sharded(rows: int = 100_000, n_queries: int = 256,
                shard_counts=(1, 2, 4, 8), batch: int = 64,
                partition: str = "range", out_path: str = None,
                backend: str = "numpy", smoke: bool = False) -> dict:
    """Scatter-gather scaling mode (DESIGN.md §6).

    For each shard count K an airline-rows ``ShardedCOAX`` (range partition
    on the distance attribute, each shard learning its own FDs) answers the
    same rect set through the executor's sharded mode, on ``backend``
    (``"numpy"`` or ``"device"`` — per-shard ``DevicePlan``s; recorded in
    the output).  Reported per K: sustained QPS vs the single-index
    baseline on the same backend, the shard-pruning rate (fraction of
    (query, shard) pairs the bbox test skipped) and the per-shard work
    rollup.  Every K's hits are asserted bit-identical to the single index
    before timing; ``smoke`` keeps that gate as the CI assertion and
    shrinks nothing else (the sweep is already small).  Results merge into
    the ``sharded`` key of ``BENCH_queries.json`` so the batch-mode
    sections survive.
    """
    from repro.engine import ShardedCOAX

    if backend not in ("numpy", "device"):
        raise ValueError(f"--shards sweeps one backend at a time, got {backend!r}")
    if backend == "device":
        from repro.engine import device_available
        if not device_available():
            raise RuntimeError("--backend device requested but jax is unavailable")
    ds = dataset("airline", rows)
    rects = np.asarray(queries("airline", rows, n_queries, PCFG.knn_k))
    single = COAXIndex(ds.data, backend=backend)
    ex1 = BatchQueryExecutor(single, max_batch=batch)
    base_hits = ex1.execute(rects)               # warm + correctness anchor
    ex1.reset_stats()
    t0 = time.perf_counter()
    ex1.execute(rects)
    single_qps = len(rects) / (time.perf_counter() - t0)
    emit("sharded/airline/single_index_qps", single_qps,
         f"rows={rows},queries={len(rects)},batch={batch},backend={backend}")

    result = {"dataset": "airline", "rows": rows, "n_queries": len(rects),
              "batch": batch, "partition": partition, "backend": backend,
              "single_qps": single_qps, "shards": {}}
    for k in shard_counts:
        idx = ShardedCOAX(ds.data, n_shards=k, partition=partition,
                          backend=backend)
        ex = BatchQueryExecutor(idx, max_batch=batch, shards=k)
        got = ex.execute(rects)                  # warm + agreement gate
        assert all(np.array_equal(g, w) for g, w in zip(got, base_hits)), \
            f"sharded hits disagree with single index at K={k}"
        ex.reset_stats()
        t0 = time.perf_counter()
        ex.execute(rects)
        dt = time.perf_counter() - t0
        qps = len(rects) / dt
        s = ex.stats()
        scattered = sum(p["queries"] for p in s["per_shard"])
        pruned = 1.0 - scattered / (len(rects) * k)
        result["shards"][str(k)] = {
            "qps": qps, "speedup_vs_single": qps / single_qps,
            "pruned_frac": pruned, "rows_scanned": s["rows_scanned"],
            "per_shard": s["per_shard"], "shard_sizes": idx.shard_sizes(),
        }
        emit(f"sharded/airline/qps@K{k}", qps,
             f"speedup={qps / single_qps:.2f}x,pruned={pruned:.2f},"
             f"rows_scanned={s['rows_scanned']}")
    if smoke:
        emit("sharded/airline/smoke", 1.0,
             f"hit agreement ok across K={list(shard_counts)} "
             f"({len(rects)} rects)")

    _write_bench_section(out_path, "BENCH_queries.json", "sharded", result)
    print(f"BENCH {json.dumps(result)}")
    return result


def run_mixed(rows: int = 50_000, n_queries: int = 192,
              insert_ratios=(0.1, 0.25, 0.5, 0.75), batch: int = 64,
              out_path: str = None, smoke: bool = False) -> dict:
    """Mixed read/write workload (DESIGN.md §5).

    For each write ratio ``r`` a fresh ``COAXIndex`` with BACKGROUND
    compaction (§5.4) is driven through a ``QueryServer``: every wave of
    ``batch`` queries is preceded by ``r/(1-r)`` write admissions —
    inserts of 32-row batches drawn from held-out airline rows (every 4th
    batch FD-VIOLATING, so the outlier delta and the drift tracker see
    real work) and deletes of 16 random original ids — flushed at the wave
    boundary under the server's per-wave snapshot semantics.  Reported per
    ratio: sustained query QPS, write throughput, the lifecycle counters
    (epoch, compactions, residual delta rows), and the SERVING-PAUSE
    profile — median / p99 / max gap between wave completions, the metric
    a synchronous stop-the-world compaction blows up and an epoch handoff
    must not.  A read-only baseline (``read_only`` key) anchors the
    "writes must not halve reads" comparison.  ``smoke`` gates every
    ratio's final state on hit agreement with a rebuild-from-scratch
    oracle (a fresh ``COAXIndex`` over ``live_rows()``), on the device
    backend too when jax is present, and gates the pause profile at
    r=0.5: no wave gap may exceed 5x the median wave latency.
    """
    from repro.engine import QueryServer

    ds = dataset("airline", rows * 2)           # second half = insert pool
    base = np.ascontiguousarray(ds.data[:rows])
    pool = ds.data[rows:].copy()
    dep_col = 1                                 # airline FD: distance -> elapsed
    rects = knn_rect_queries(base, n_queries, PCFG.knn_k,
                             seed=PCFG.seed, sample_cap=100_000)
    result = {"dataset": "airline", "rows": rows, "n_queries": int(n_queries),
              "batch": batch, "insert_rows_per_op": 32, "ratios": {}}

    def _drive(idx, ratio):
        """One sweep of the query waves at write ratio ``ratio``; returns
        the server, elapsed seconds and per-wave completion gaps."""
        srv = QueryServer(idx, max_batch=batch)
        rng = np.random.default_rng(PCFG.seed + int(ratio * 1000))
        pool_pos, n_ins_batches = 0, 0
        writes_per_wave = ratio / max(1.0 - ratio, 1e-9)
        owed = 0.0
        t0 = time.perf_counter()
        done = []
        for start in range(0, len(rects), batch):
            wave = rects[start:start + batch]
            owed += writes_per_wave * len(wave)
            while owed >= 1.0:
                owed -= 1.0
                if n_ins_batches % 3 == 2:      # 1 delete per 2 inserts
                    srv.delete(rng.integers(0, rows, 16))
                else:
                    rows_in = pool[pool_pos:pool_pos + 32].copy()
                    pool_pos = (pool_pos + 32) % max(len(pool) - 32, 1)
                    if n_ins_batches % 8 == 6:  # FD-violating burst
                        rows_in[:, dep_col] = rows_in[:, dep_col] * 3.0 + 500.0
                    srv.insert(rows_in)
                n_ins_batches += 1
            for r in wave:
                srv.submit(r)
            srv.drain()
            done.append(time.perf_counter())
        gaps = np.diff(np.asarray([t0] + done))
        return srv, done[-1] - t0, gaps

    _drive(COAXIndex(base), 0.0)                # warmup (first drive in a
    _, ro_dt, _ = _drive(COAXIndex(base), 0.0)  # process runs several x cold)
    ro_qps = len(rects) / ro_dt
    result["read_only"] = {"qps": ro_qps}
    emit("mixed/airline/qps@read_only", ro_qps, "no write admissions")

    for ratio in insert_ratios:
        idx = COAXIndex(base, CoaxConfig(background_compact=True))
        srv, dt, gaps = _drive(idx, ratio)
        idx.finish_handoff()                    # join any in-flight build
        s = srv.stats()
        entry = {
            "qps": len(rects) / dt,
            "writes_per_s": s["writes_applied"] / dt,
            "rows_inserted": s["rows_inserted"],
            "rows_deleted": s["rows_deleted"],
            "epoch": s["epoch"],
            "compactions": s["compactions"],
            "background_compactions": idx.background_compactions,
            "final_delta_rows": s["delta_rows"],
            "final_tombstones": s["tombstones"],
            "wave_median_ms": float(np.median(gaps) * 1e3),
            "pause_p99_ms": float(np.percentile(gaps, 99) * 1e3),
            "pause_max_ms": float(np.max(gaps) * 1e3),
        }
        result["ratios"][str(ratio)] = entry
        emit(f"mixed/airline/qps@r{ratio}", entry["qps"],
             f"writes/s={entry['writes_per_s']:.1f},"
             f"inserted={entry['rows_inserted']},deleted={entry['rows_deleted']},"
             f"epoch={entry['epoch']},compactions={entry['compactions']},"
             f"pause_max={entry['pause_max_ms']:.1f}ms,"
             f"wave_median={entry['wave_median_ms']:.1f}ms")

        if smoke:
            if ratio == 0.5:
                # the serving-pause gate: a stop-the-world compaction shows
                # up as one wave gap many multiples of the median; the §5.4
                # handoff keeps the profile flat
                assert entry["pause_max_ms"] <= 5 * entry["wave_median_ms"], \
                    (f"serving pause {entry['pause_max_ms']:.1f}ms exceeds "
                     f"5x median wave {entry['wave_median_ms']:.1f}ms")
                emit("mixed/airline/pause@r0.5", entry["pause_max_ms"],
                     f"<= 5x median ({entry['wave_median_ms']:.1f}ms) ok")
            # rebuild-from-scratch oracle: a fresh index over the final live
            # row set must agree bit-for-bit with the mutated index
            live, ids = idx.live_rows()
            oracle = COAXIndex(live, row_ids=ids)
            got = idx.query_batch_split(np.asarray(rects))
            want = oracle.query_batch_split(np.asarray(rects))
            assert all(np.array_equal(g, w) for g, w in zip(got, want)), \
                f"mixed-wave hits disagree with scratch oracle at r={ratio}"
            from repro.engine import device_available
            if device_available():
                idx.backend = "device"
                got_d = idx.query_batch_split(np.asarray(rects))
                idx.backend = "numpy"
                assert all(np.array_equal(g, w) for g, w in zip(got_d, want)), \
                    f"device mixed-wave hits disagree with oracle at r={ratio}"
            assert s["writes_applied"] > 0 and s["rows_inserted"] > 0
            emit(f"mixed/airline/smoke@r{ratio}", 1.0,
                 f"oracle agreement ok ({len(rects)} rects)")

    _write_bench_section(out_path, "BENCH_updates.json", "mixed", result)
    print(f"BENCH {json.dumps(result)}")
    return result


def run_recover(rows: int = 100_000, n_queries: int = 128,
                wal_lengths=(0, 64, 256, 1024), out_path: str = None,
                smoke: bool = False) -> dict:
    """Durability mode (DESIGN.md §7): cost of the crash-safety plane.

    One airline-rows ``COAXIndex`` is journaled into a scratch directory;
    reported: full-state snapshot bytes vs raw data bytes, (atomic) save
    latency, cold restore latency at WAL length 0, then — for each WAL
    length W — the recovery time of a crash after W journaled write ops
    (every 4th op a delete, every 8th an FD-violating insert burst, 32 rows
    per insert) and the replayed-record count.  Every recovery is gated on
    flat-hit agreement with the never-crashed index (``smoke`` keeps the
    gate and shrinks the sweep).  Results land in the ``recover`` section
    of ``BENCH_storage.json``; other sections are merge-preserved.
    """
    import shutil
    import tempfile

    from repro.storage import read_manifest, restore, snapshot_nbytes

    if smoke:
        wal_lengths = tuple(w for w in wal_lengths if w <= 256) or (0, 64)
    ds = dataset("airline", rows * 2)            # second half = insert pool
    base = np.ascontiguousarray(ds.data[:rows])
    pool = ds.data[rows:].copy()
    rects = np.asarray(queries("airline", rows, n_queries, PCFG.knn_k))
    result = {"dataset": "airline", "rows": rows, "n_queries": len(rects),
              "data_bytes": int(base.nbytes), "wal": {}}

    idx = COAXIndex(base, CoaxConfig(auto_compact=False))
    live_hits = idx.query_batch_split(rects)
    workdir = Path(tempfile.mkdtemp(prefix="bench_recover_"))
    try:
        t0 = time.perf_counter()
        snap = idx.save(workdir / "cold")
        result["save_s"] = time.perf_counter() - t0
        result["snapshot_bytes"] = snapshot_nbytes(snap)
        emit("recover/airline/save_s", result["save_s"],
             f"snapshot={result['snapshot_bytes']}B,"
             f"data={result['data_bytes']}B")
        t0 = time.perf_counter()
        cold = restore(workdir / "cold")
        result["restore_cold_s"] = time.perf_counter() - t0
        emit("recover/airline/restore_cold_s", result["restore_cold_s"],
             "warm restart, zero-length WAL")
        assert all(np.array_equal(g, w) for g, w in
                   zip(cold.query_batch_split(rects), live_hits)), \
            "cold restore disagrees with live index"

        for w in wal_lengths:
            d = workdir / f"wal_{w}"
            vic = COAXIndex(base, CoaxConfig(auto_compact=False))
            vic.attach_durability(d)
            rng = np.random.default_rng(PCFG.seed)
            pos = 0
            for op in range(w):
                if op % 4 == 3:
                    vic.delete(rng.integers(0, rows, 16))
                else:
                    rows_in = pool[pos:pos + 32].copy()
                    pos = (pos + 32) % max(len(pool) - 32, 1)
                    if op % 8 == 6:
                        rows_in[:, 1] = rows_in[:, 1] * 3.0 + 500.0
                    vic.insert(rows_in)
            vic.durable.sync()
            want = vic.query_batch_split(rects)
            wal_bytes = vic.durable.describe()["wal_bytes"]
            del vic                               # crash
            t0 = time.perf_counter()
            rec = restore(d, durable=True)
            dt = time.perf_counter() - t0
            assert all(np.array_equal(g, x) for g, x in
                       zip(rec.query_batch_split(rects), want)), \
                f"recovery disagrees with live index at WAL length {w}"
            result["wal"][str(w)] = {
                "recovery_s": dt, "wal_bytes": int(wal_bytes),
                "replayed": int(rec.durable.wal.next_seq),
            }
            emit(f"recover/airline/recovery_s@wal{w}", dt,
                 f"wal_bytes={wal_bytes},agreement=ok")
        if smoke:
            emit("recover/airline/smoke", 1.0,
                 f"recovered==live at WAL lengths {list(wal_lengths)} "
                 f"({len(rects)} rects)")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    _write_bench_section(out_path, "BENCH_storage.json", "recover", result)
    print(f"BENCH {json.dumps(result)}")
    return result


def run_failover(rows: int = 50_000, n_queries: int = 96, n_ops: int = 48,
                 out_path: str = None, smoke: bool = False) -> dict:
    """Replication mode (DESIGN.md §8): cost + correctness of failover.

    A 2-replica ``ReplicatedServer`` over airline rows streams an
    insert/delete schedule while a scripted ``FaultPlan`` damages the wire
    (drops, torn frames, duplicates, reordering, transport errors — all
    repaired through catch-up).  Reported: shipping overhead on the write
    path, frames/bytes shipped, replica convergence (lag drained to 0),
    then two failover drills — the primary killed MID-STREAM with an
    acked-but-unpumped tail, and killed MID-COMPACTION-ROTATION (the §7.5
    crash window) — each measuring promotion latency and gating the
    promoted frontier ≥ the last acked write.  Every stage asserts
    bit-identical flat hits against a never-crashed oracle index replaying
    the same ops.  Results land in the ``failover`` section of
    ``BENCH_storage.json``; other sections are merge-preserved.
    """
    import shutil
    import tempfile

    from repro.replication import ReplicatedServer
    from repro.runtime.failure import FaultPlan

    if smoke:
        n_ops = min(n_ops, 24)
    ds = dataset("airline", rows * 2)            # second half = insert pool
    base = np.ascontiguousarray(ds.data[:rows])
    pool = ds.data[rows:].copy()
    rects = np.asarray(queries("airline", rows, n_queries, PCFG.knn_k))
    result = {"dataset": "airline", "rows": rows, "n_queries": len(rects),
              "n_ops": n_ops}

    def op_stream(target, upto):
        rng = np.random.default_rng(PCFG.seed)
        pos = 0
        for op in range(upto):
            if op % 4 == 3:
                target.delete(rng.integers(0, rows, 16))
            else:
                rows_in = pool[pos:pos + 48].copy()
                pos += 48
                if op % 8 == 6:
                    rows_in[:, 1] = rows_in[:, 1] * 3.0 + 500.0
                target.insert(rows_in)
            yield op

    def flat(index):
        return index.query_batch_split(rects)

    def agree(a, b):
        return all(np.array_equal(x, y) for x, y in zip(a, b))

    workdir = Path(tempfile.mkdtemp(prefix="bench_failover_"))
    try:
        # ---------------- drill 1: faulty wire + mid-stream kill -------- #
        plan = FaultPlan({
            "ship.replica-0": {3: "drop", 7: "tear", 11: "dup",
                               15: ("tear", 9), 19: "drop"},
            "ship.replica-1": {5: "reorder", 9: ("error", 2),
                               13: ("delay", 2), 17: "tear"},
        })
        oracle = COAXIndex(base.copy(), CoaxConfig(auto_compact=False))
        srv = ReplicatedServer(
            COAXIndex(base, CoaxConfig(auto_compact=False)), workdir / "d1",
            n_replicas=2, plan=plan)
        t0 = time.perf_counter()
        for op in op_stream(srv, n_ops):
            if op % 3 == 2:
                srv.tick()
        write_s = time.perf_counter() - t0
        for _ in op_stream(oracle, n_ops):
            pass
        srv.compact()                            # ships the ROTATE frame
        oracle.compact()
        t0 = time.perf_counter()
        for _ in range(16):
            srv.tick()
            if all(r.lag_frames() == 0 for r in srv.replicas):
                break
        converge_s = time.perf_counter() - t0
        st = srv.stats()
        assert all(r["lag_frames"] == 0 for r in st["replicas"]), \
            "replicas failed to drain their lag"
        want = flat(oracle)
        for rep in srv.replicas:
            assert agree(flat(rep.index), want), \
                f"{rep.name} diverged from the never-crashed oracle"
        result["ship"] = {
            "write_path_s": write_s, "converge_s": converge_s,
            "frames": st["ship"]["shipped_frames"],
            "bytes": st["ship"]["shipped_bytes"],
            "send_retries": st["ship"]["send_retries"],
            "transport_faults": st["transport_faults"],
        }
        emit("failover/airline/ship_frames", st["ship"]["shipped_frames"],
             f"bytes={st['ship']['shipped_bytes']},"
             f"faults={sum(st['transport_faults'].values())},agreement=ok")

        # primary dies with an acked tail the replicas never saw shipped
        srv.insert(pool[-64:])
        oracle.insert(pool[-64:])
        acked = srv.acked
        srv.kill_primary()
        t0 = time.perf_counter()
        promoted = srv.promote()
        promote_s = time.perf_counter() - t0
        assert promoted.frontier >= acked, \
            f"promotion lost acked writes: {promoted.frontier} < {acked}"
        assert agree(flat(promoted.index), flat(oracle)), \
            "promoted index diverged from the never-crashed oracle"
        for op in op_stream(srv, 4):             # writes resume post-promotion
            srv.tick()
        for _ in op_stream(oracle, 4):
            pass
        for _ in range(8):
            srv.tick()
        assert agree(flat(srv.primary), flat(oracle)), \
            "post-promotion writes diverged from the oracle"
        result["promote_midstream_s"] = promote_s
        emit("failover/airline/promote_midstream_s", promote_s,
             f"frontier={promoted.frontier}>=acked={acked},agreement=ok")

        # ---------------- drill 2: kill mid-compaction-rotation --------- #
        plan2 = FaultPlan({"primary.rotate": {0: "crash"}})
        oracle2 = COAXIndex(base.copy(), CoaxConfig(auto_compact=False))
        srv2 = ReplicatedServer(
            COAXIndex(base, CoaxConfig(auto_compact=False)), workdir / "d2",
            n_replicas=2, plan=plan2)
        for op in op_stream(srv2, n_ops // 2):
            if op % 3 == 2:
                srv2.tick()
        for _ in op_stream(oracle2, n_ops // 2):
            pass
        acked2 = srv2.acked
        try:
            srv2.compact()                       # dies inside the §7.5 window
            raise AssertionError("rotation crash did not fire")
        except RuntimeError:
            pass
        oracle2.compact()                        # ...but the rotation is on disk
        srv2.kill_primary()
        t0 = time.perf_counter()
        promoted2 = srv2.promote()
        promote2_s = time.perf_counter() - t0
        assert promoted2.frontier >= acked2
        assert promoted2.index.epoch == oracle2.epoch
        assert agree(flat(promoted2.index), flat(oracle2)), \
            "mid-rotation promotion diverged from the never-crashed oracle"
        result["promote_midrotation_s"] = promote2_s
        emit("failover/airline/promote_midrotation_s", promote2_s,
             f"epoch={promoted2.index.epoch},agreement=ok")
        if smoke:
            emit("failover/airline/smoke", 1.0,
                 f"bit-identity held over {n_ops} ops, 2 kills, "
                 f"{sum(st['transport_faults'].values())} wire faults "
                 f"({len(rects)} rects)")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    _write_bench_section(out_path, "BENCH_storage.json", "failover", result)
    print(f"BENCH {json.dumps(result)}")
    return result


def run_cache(rows: int = 100_000, n_queries: int = 512, n_hot: int = 16,
              batch: int = 64, cache_mb: int = 64, out_path: str = None,
              smoke: bool = False) -> dict:
    """Semantic-cache mode (DESIGN.md §9): the Zipfian cache sweep.

    A ``zipf_rects`` hot-rect stream (repeats = exact hits, nested subsets
    = containment partials, per the "Benchmarking Learned Indexes" advice
    to gate on a skewed mix rather than uniform rects) is answered three
    ways on one airline index: uncached (the baseline + bit-identity
    oracle), a cold cached pass (admissions + partials), and a warm cached
    pass (the steady state the QPS claim is about).  Then the §9.3 MVCC
    drill: a pinned reader on a background-compacting index must answer
    bit-identically to pin time across a real epoch handoff, and the old
    epoch must stay alive until release.

    ``smoke`` turns the gates into hard assertions for CI: cache-on ≡
    cache-off flat hits, ``cache_hit_rate > 0``, and pinned-reader
    agreement.  Results land in the ``cache`` section of
    ``BENCH_queries.json``; other sections are merge-preserved.
    """
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tests"))
    from workloads import zipf_rects

    ds = dataset("airline", rows)
    rects = zipf_rects(ds.data, n=n_queries, n_hot=n_hot, seed=PCFG.seed,
                       sample_cap=min(rows, 100_000))
    idx = COAXIndex(ds.data)

    ex0 = BatchQueryExecutor(idx, max_batch=batch)
    want = ex0.execute(rects)                    # warm pass
    ex0.reset_stats()
    t0 = time.perf_counter()
    ex0.execute(rects)
    uncached_qps = len(rects) / (time.perf_counter() - t0)
    emit("cache/airline/uncached_qps", uncached_qps,
         f"rows={rows},queries={len(rects)},n_hot={n_hot},batch={batch}")

    idx.attach_cache(byte_budget=cache_mb << 20)
    ex = BatchQueryExecutor(idx, max_batch=batch)
    cold = ex.execute(rects)                     # populates + partial hits
    assert all(np.array_equal(g, w) for g, w in zip(cold, want)), \
        "cold cached pass disagrees with the uncached oracle"
    cold_stats = ex.stats()
    ex.reset_stats()
    t0 = time.perf_counter()
    warm = ex.execute(rects)
    cached_qps = len(rects) / (time.perf_counter() - t0)
    assert all(np.array_equal(g, w) for g, w in zip(warm, want)), \
        "warm cached pass disagrees with the uncached oracle"
    s = ex.stats()
    result = {
        "dataset": "airline", "rows": rows, "n_queries": len(rects),
        "n_hot": n_hot, "batch": batch, "cache_mb": cache_mb,
        "uncached_qps": uncached_qps, "cached_qps": cached_qps,
        "cache_speedup": cached_qps / uncached_qps,
        "cold_hit_rate": cold_stats["cache_hit_rate"],
        "warm_hit_rate": s["cache_hit_rate"],
        "cache_bytes": s["cache_bytes"],
        "cache": idx.cache.describe(),
    }
    emit("cache/airline/cached_qps", cached_qps,
         f"speedup={result['cache_speedup']:.2f}x,"
         f"warm_hit_rate={s['cache_hit_rate']:.3f},"
         f"cache_bytes={s['cache_bytes']}")

    # ---------------- §9.3 MVCC drill: pin across a real handoff -------- #
    mvcc_rows = min(rows, 20_000)
    bg = COAXIndex(ds.data[:mvcc_rows],
                   CoaxConfig(background_compact=True, compact_min_delta=512,
                              compact_delta_frac=0.01, compact_check_rows=32))
    mvcc_rects = rects[:min(64, len(rects))]
    pin = bg.pin_epoch()
    pinned_want = pin.query_batch_split(mvcc_rects)
    rng = np.random.default_rng(PCFG.seed)
    t0 = time.perf_counter()
    while bg.background_compactions < 1:
        bg.insert(ds.data[rng.integers(0, mvcc_rows, 128)])
        bg.poll_handoff(wait=True)
    bg.finish_handoff()
    handoff_s = time.perf_counter() - t0
    pinned_got = pin.query_batch_split(mvcc_rects)
    mvcc_ok = all(np.array_equal(g, w)
                  for g, w in zip(pinned_got, pinned_want))
    assert mvcc_ok, "pinned reader diverged across the background handoff"
    assert bg.epoch > pin.epoch
    pin.release()
    result["mvcc"] = {
        "pinned_agreement": mvcc_ok, "pinned_epoch": pin.epoch,
        "live_epoch": bg.epoch, "handoffs": bg.background_compactions,
        "handoff_drive_s": handoff_s,
    }
    emit("cache/airline/mvcc_pin", 1.0,
         f"pinned@{pin.epoch} bit-identical across handoff to "
         f"epoch {bg.epoch} ({len(mvcc_rects)} rects)")

    if smoke:
        assert s["cache_hit_rate"] > 0, "warm pass produced no cache hits"
        emit("cache/airline/smoke", 1.0,
             f"cache-on == cache-off ({len(rects)} rects), "
             f"warm_hit_rate={s['cache_hit_rate']:.3f}, mvcc pin ok")

    _write_bench_section(out_path, "BENCH_queries.json", "cache", result)
    print(f"BENCH {json.dumps(result)}")
    return result


def run_telemetry(rows: int = 100_000, n_queries: int = 512, batch: int = 64,
                  out_path: str = None, smoke: bool = False,
                  backend: str = "numpy") -> dict:
    """Telemetry mode (DESIGN.md §10): the observability plane's own gate.

    Drives one airline read sweep twice — tracing OFF then tracing ON
    (best-of-3 each, same rects, same executor) — and a short mixed
    write phase with background compaction, then reports:

    * per-stage wall breakdown (probe/search/filter/merge/delta_scan/
      cache/dispatch/transfer/fsync) from ``coax_stage_seconds``;
    * the tracing overhead ratio (instrumented vs not);
    * trace structure health (``Tracer.validate``) + exposition
      round-trip (``render_text`` -> ``parse_text_exposition``);
    * serving-pause attribution from the §10.3 watchdog.

    ``smoke`` turns the §10.4 budget into hard CI assertions: overhead
    ≤5% QPS, the trace validates, the exposition parses, and tracing-on
    answers stay bit-identical to tracing-off.  Results land in the
    ``telemetry`` section of ``BENCH_queries.json``.
    """
    from repro import obs
    from repro.engine import QueryServer

    ds = dataset("airline", rows)
    rects = np.asarray(queries("airline", rows, n_queries, PCFG.knn_k))
    idx = COAXIndex(ds.data)
    ex = BatchQueryExecutor(idx, max_batch=batch, backend=backend)
    want = ex.execute(rects)                     # warm (jit, page-in)

    def timed():
        t0 = time.perf_counter()
        got = ex.execute(rects)
        return len(rects) / (time.perf_counter() - t0), got

    # interleave tracing-on/off samples so machine drift (frequency
    # scaling, page cache, sibling load) cancels instead of landing
    # entirely on one side of the §10.4 overhead ratio
    tr = obs.enable_tracing(capacity=65536)
    obs.set_tracer(None)
    try:
        off_s, on_s = [], []
        got_off = got_on = None
        for _ in range(5):
            obs.set_tracer(None)
            q, got_off = timed()
            off_s.append(q)
            obs.set_tracer(tr)
            q, got_on = timed()
            on_s.append(q)
        qps_off, qps_on = max(off_s), max(on_s)
        identical = all(np.array_equal(a, b) for a, b in zip(got_on, want)) \
            and all(np.array_equal(a, b) for a, b in zip(got_off, want))
        overhead = 1.0 - qps_on / qps_off

        # ------- short mixed phase: pause attribution under compaction --- #
        bg = COAXIndex(ds.data[:min(rows, 30_000)].copy(),
                       CoaxConfig(background_compact=True,
                                  compact_min_delta=512,
                                  compact_delta_frac=0.01,
                                  compact_check_rows=64))
        srv = QueryServer(bg, max_batch=batch)
        rng = np.random.default_rng(PCFG.seed)
        for _ in range(2):                       # enough waves to cross the
            for start in range(0, len(rects), batch):   # compaction trigger
                srv.insert(ds.data[rng.integers(0, len(ds.data), 128)])
                for r in rects[start:start + batch]:
                    srv.submit(r)
                srv.drain()
        bg.finish_handoff()
        ss = srv.stats()
        # validate AFTER the mixed phase so compaction/WAL spans are in
        # scope too, not just the read sweep's wave spans
        ok, problems = tr.validate()

        text = obs.get_registry().render_text()
        parsed = obs.parse_text_exposition(text)

        stages = {}
        hist = obs.stage_hist()
        for series in obs.get_registry().snapshot() \
                         .get("coax_stage_seconds", {}).get("series", []):
            lab = series["labels"]
            summ = hist.summary(**lab)
            if summ["count"]:
                stages[f"{lab['stage']}/{lab['backend']}"] = {
                    "count": summ["count"], "total_s": summ["sum"],
                    "p50_us": summ["p50"] * 1e6, "p99_us": summ["p99"] * 1e6,
                }

        result = {
            "dataset": "airline", "rows": rows, "n_queries": len(rects),
            "batch": batch, "backend": backend,
            "qps_tracing_off": qps_off, "qps_tracing_on": qps_on,
            "tracing_overhead": overhead,
            "bit_identical": bool(identical),
            "trace_valid": bool(ok), "trace_problems": problems[:8],
            "trace_events": len(tr.events()), "trace_dropped": tr.dropped,
            "exposition_families": len(parsed),
            "stages": stages,
            "pauses": {
                "count": int(ss.get("pauses", 0)),
                "median_gap_s": ss.get("pause_median_gap_s", 0.0),
                "last_culprit": ss.get("last_pause_culprit"),
            },
            "compactions": {
                "background": bg.background_compactions,
                "handoff_s": bg.last_handoff_s,
            },
        }
        emit("telemetry/airline/overhead", overhead * 100,
             f"qps_off={qps_off:.0f},qps_on={qps_on:.0f},"
             f"events={result['trace_events']},"
             f"families={result['exposition_families']}")
        for k, v in sorted(stages.items()):
            emit(f"telemetry/airline/stage/{k}", v["p50_us"],
                 f"count={v['count']},total_s={v['total_s']:.4f}")

        if smoke:
            assert identical, \
                "tracing-on answers diverged from tracing-off"
            assert ok, f"trace failed validation: {problems[:4]}"
            assert parsed, "text exposition failed to parse"
            assert "coax_stage_seconds" in parsed, \
                "stage histogram missing from exposition"
            assert overhead <= 0.05, \
                f"tracing overhead {overhead:.1%} exceeds the 5% budget"
            emit("telemetry/airline/smoke", 1.0,
                 f"overhead={overhead:.2%}<=5%, trace ok, "
                 f"{len(parsed)} families parsed")
    finally:
        obs.disable_tracing()

    _write_bench_section(out_path, "BENCH_queries.json", "telemetry", result)
    print(f"BENCH {json.dumps(result)}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", action="store_true",
                    help="throughput mode: QPS vs batch size + BENCH_queries.json")
    ap.add_argument("--mixed", action="store_true",
                    help="read/write mode: insert-ratio sweep + BENCH_updates.json")
    ap.add_argument("--shards", type=str, default=None, metavar="K[,K...]",
                    help="sharded mode: scatter-gather scaling sweep over "
                         "these shard counts (DESIGN.md §6)")
    ap.add_argument("--recover", action="store_true",
                    help="durability mode: snapshot/save/recovery costs + "
                         "BENCH_storage.json (DESIGN.md §7)")
    ap.add_argument("--failover", action="store_true",
                    help="replication mode: WAL shipping under faults, "
                         "promotion drills + BENCH_storage.json (DESIGN.md §8)")
    ap.add_argument("--cache", action="store_true",
                    help="semantic-cache mode: Zipfian hot-rect sweep + "
                         "MVCC pin drill + BENCH_queries.json (DESIGN.md §9)")
    ap.add_argument("--telemetry", action="store_true",
                    help="telemetry mode: per-stage breakdown, tracing "
                         "overhead gate + BENCH_queries.json (DESIGN.md §10)")
    ap.add_argument("--backend", choices=("numpy", "device", "both"),
                    default="both", help="which query_batch backend(s) to sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep + hard throughput/agreement asserts (CI)")
    ap.add_argument("--rows", type=int, default=None)
    ap.add_argument("--queries", type=int, default=None)
    args = ap.parse_args()
    if args.telemetry:
        run_telemetry(rows=args.rows or 100_000,
                      n_queries=args.queries or (256 if args.smoke else 512),
                      smoke=args.smoke,
                      backend="numpy" if args.backend == "both"
                      else args.backend)
    elif args.cache:
        run_cache(rows=args.rows or 100_000,
                  n_queries=args.queries or (192 if args.smoke else 512),
                  smoke=args.smoke)
    elif args.failover:
        run_failover(rows=args.rows or 50_000,
                     n_queries=args.queries or (48 if args.smoke else 96),
                     smoke=args.smoke)
    elif args.recover:
        run_recover(rows=args.rows or 100_000,
                    n_queries=args.queries or (64 if args.smoke else 128),
                    smoke=args.smoke)
    elif args.shards:
        counts = tuple(int(k) for k in args.shards.split(","))
        run_sharded(rows=args.rows or 100_000,
                    n_queries=args.queries or (64 if args.smoke else 256),
                    shard_counts=counts, smoke=args.smoke,
                    # --backend both is the batch-mode default; the sharded
                    # sweep runs one backend per invocation
                    backend="numpy" if args.backend == "both" else args.backend)
    elif args.mixed:
        # smoke still sweeps enough waves (256/64 = 4 per ratio) for the
        # serving-pause profile to mean something
        run_mixed(rows=args.rows or 50_000,
                  n_queries=args.queries or (256 if args.smoke else 192),
                  smoke=args.smoke)
    elif args.batch:
        run_batch(rows=args.rows or 100_000,
                  n_queries=args.queries or (64 if args.smoke else 256),
                  backend=args.backend, smoke=args.smoke)
    else:
        run(rows=args.rows, n_queries=args.queries)
