"""Kernel-layer microbenchmarks (paper §6 scan / §5 bucketing hot loops).

Interpret-mode Pallas is a CPU correctness harness, not a fast path, so the
throughput numbers here time the jnp oracle (XLA-compiled, identical math)
and the equivalent numpy engine path; the Pallas kernels are asserted
equivalent on a sample then timed separately so their interpret-mode cost is
visible but not confused with device throughput.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit
from repro.kernels import bucket_histogram, range_scan_query, split_by_margin


def _time(fn, *args, repeats=5, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def run(n: int = 1_000_000) -> dict:
    rng = np.random.default_rng(0)
    out = {}

    # range_scan: D=8 column-major block
    rows = rng.normal(0, 5, (8, n)).astype(np.float32)
    lo = np.full(8, -4, np.float32)
    hi = np.full(8, 4, np.float32)
    us_ref = _time(lambda: range_scan_query(rows, lo, hi, use_pallas=False)[0])
    out["range_scan_ref"] = us_ref
    emit("kernels/range_scan/jnp_oracle", us_ref, f"n={n} rows, D=8")
    c1, m1 = range_scan_query(rows[:, :8192], lo, hi, use_pallas=True)
    c2, m2 = range_scan_query(rows[:, :8192], lo, hi, use_pallas=False)
    assert int(c1) == int(c2)
    us_pal = _time(lambda: range_scan_query(rows[:, :8192], lo, hi,
                                            use_pallas=True)[0], repeats=2)
    emit("kernels/range_scan/pallas_interpret", us_pal, "n=8192 (correctness mode)")

    # grid_histogram (Alg. 1 bucketing)
    x = rng.normal(0, 3, n).astype(np.float32)
    d = rng.gamma(2.0, 2.0, n).astype(np.float32)
    us_h = _time(lambda: bucket_histogram(x, d, buckets=64, use_pallas=False))
    out["grid_histogram_ref"] = us_h
    emit("kernels/grid_histogram/jnp_oracle", us_h, f"n={n}, 64x64")
    h1 = bucket_histogram(x[:8192], d[:8192], buckets=64, use_pallas=True)
    h2 = bucket_histogram(x[:8192], d[:8192], buckets=64, use_pallas=False)
    assert float(jnp.abs(h1 - h2).max()) == 0.0

    # margin_split (Alg. 1 split)
    dd = (2.0 * x + 5 + rng.normal(0, 2, n)).astype(np.float32)
    us_m = _time(lambda: split_by_margin(x, dd, 2.0, 5.0, 4.0, 4.0,
                                         use_pallas=False)[1])
    out["margin_split_ref"] = us_m
    emit("kernels/margin_split/jnp_oracle", us_m, f"n={n}")
    return out


if __name__ == "__main__":
    run()
