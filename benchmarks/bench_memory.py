"""Fig. 8: range-query runtime vs index memory footprint.

Sweeps the per-index resolution knob (cells_per_dim / R-tree node size) and
reports (memory_bytes, us_per_query) pairs — the tradeoff curves whose gap
is the paper's four-orders-of-magnitude headline.  Table 1's dataset
statistics are also reproduced here (primary ratios, detected groups).
"""
from __future__ import annotations

import time

import numpy as np

from .common import PCFG, dataset, emit, queries, time_queries
from repro.core import COAXIndex, CoaxConfig, ColumnFiles, STRTree, UniformGrid


def run(rows: int = None, n_queries: int = 80) -> dict:
    rows = rows or PCFG.airline_rows
    ds = dataset("airline", rows)
    rects = queries("airline", rows, n_queries, PCFG.knn_k)
    out = {}

    sweeps = {
        "coax": [4, 8, 16, 32, 64],
        "column_files": [2, 3, 4, 6, 8],
        "uniform_grid": [2, 3, 4, 6, 8],
        "r_tree": [6, 10, 16, 32],
    }
    for name, knob_vals in sweeps.items():
        best = None
        for v in knob_vals:
            if name == "coax":
                eng = COAXIndex(ds.data, CoaxConfig(primary_cells_per_dim=v))
            elif name == "column_files":
                eng = ColumnFiles(ds.data, cells_per_dim=v)
            elif name == "uniform_grid":
                eng = UniformGrid(ds.data, cells_per_dim=v)
            else:
                eng = STRTree(ds.data, leaf_cap=v, node_cap=v)
            us, _ = time_queries(eng, rects)
            mem = eng.memory_footprint()
            out[(name, v)] = {"us": us, "bytes": mem}
            emit(f"fig8/{name}/knob={v}", us, f"mem_bytes={mem}")
            if best is None or us < best[0]:
                best = (us, mem, v)
        out[(name, "best")] = {"us": best[0], "bytes": best[1], "knob": best[2]}
        emit(f"fig8/{name}/best", best[0], f"mem_bytes={best[1]},knob={best[2]}")

    # headline: memory ratio at each index's best-latency point
    ratio = out[("uniform_grid", "best")]["bytes"] / max(out[("coax", "best")]["bytes"], 1)
    emit("fig8/memory_ratio_uniform_vs_coax_at_best", ratio, "x (paper: ~1e4)")
    return out


def table1(rows: int = None) -> dict:
    """Table 1: dataset characteristics + what COAX detects."""
    rows = rows or PCFG.airline_rows
    out = {}
    for name in ("airline", "osm"):
        ds = dataset(name, rows)
        t0 = time.time()
        cx = COAXIndex(ds.data)
        build = time.time() - t0
        d = cx.describe()
        out[name] = d
        emit(f"table1/{name}/primary_ratio", d["primary_ratio"] * 100, "%")
        emit(f"table1/{name}/indexed_dims", len(d["indexed_dims"]),
             f"groups={[(g['predictor'], g['dependents']) for g in d['groups']]}")
        emit(f"table1/{name}/build_s", build, f"rows={rows}")
    return out


if __name__ == "__main__":
    run()
    table1()
